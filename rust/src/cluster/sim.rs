//! Slot-based cluster engine (the CarbonFlex-Simulator of paper §5).
//!
//! [`ClusterEngine`] owns the per-job runtime state and advances one slot at
//! a time: admit arrivals, build the policy's [`SlotCtx`] view, apply its
//! [`Decision`], enforce the invariants the prototype's Slurm substrate
//! enforced (capacity cap, SLO force-run, checkpoint cost on rescale, boot
//! lag energy on scale-up), advance job progress by each job's throughput
//! profile, and integrate energy and carbon per Eq. 1–3.
//!
//! Two drivers share the engine: [`Simulator::run`] (batch: replay a whole
//! trace until drain) and the live [`crate::coordinator`] service (jobs are
//! submitted over a channel and slots tick in real or virtual time).
//!
//! §Perf: `step` is the system's innermost loop (every sweep cell, oracle
//! replay, and coordinator tick funnels through it), so its steady state is
//! allocation-free: the active-job list, policy views, decision, and all
//! sanitizer scratch live in reusable engine fields, and slot records store
//! queue lengths inline. `tests/zero_alloc.rs` enforces the invariant with
//! a counting global allocator.
//!
//! The hot state is **structure-of-arrays**: per-job runtime state lives in
//! [`JobColumns`] (parallel `f64`/`u32` columns indexed by dense job id),
//! per-slot records accumulate in [`SlotColumns`] (one column per
//! [`SlotRecord`] field, queue lengths flattened), and the policy sees a
//! [`crate::sched::JobViewCols`] mirror of the view slice — so the advance
//! loop, sanitize, and the Table 2 feature extraction are branch-light
//! index loops over contiguous arrays. Output is bitwise-identical to the
//! old array-of-structs engine, pinned by the in-test AoS reference
//! (`aos_reference_run`) and the golden-fingerprint harness.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::carbon::forecast::Forecaster;
use crate::cluster::energy::EnergyModel;
use crate::cluster::metrics::{JobOutcome, RunMetrics};
use crate::faults::FaultPlan;
use crate::sched::{Decision, JobView, JobViewCols, Policy, SlotCtx, MAX_QUEUES};
use crate::util::stats;
use crate::workload::job::Job;

/// Per-slot record of what the policy did — the raw material for the
/// learning phase's `(STATE → m_t, ρ)` mappings (paper §4.2) and for
/// plotting capacity curves. During a run the engine stores these as
/// [`SlotColumns`]; the record form is materialized for [`SimResult`].
#[derive(Debug, Clone, Default)]
pub struct SlotRecord {
    pub t: usize,
    /// Carbon intensity this slot, g/kWh.
    pub ci: f64,
    /// Capacity the policy provisioned (after clamping to M).
    pub provisioned: usize,
    /// Servers actually allocated to jobs.
    pub used: usize,
    /// Implied scheduling threshold ρ: the smallest marginal throughput
    /// among granted servers; 1.0 when only base allocations ran;
    /// [`RHO_IDLE`] when jobs were queued but nothing ran.
    pub rho: f64,
    /// Active jobs per queue at decision time (entries past the simulator's
    /// `num_queues` are zero; inline so slot records stay off the heap).
    pub queue_lengths: [usize; MAX_QUEUES],
    /// Mean elasticity of active jobs.
    pub mean_elasticity: f64,
    /// Energy consumed this slot, kWh (jobs only).
    pub energy_kwh: f64,
    /// Carbon emitted this slot, grams (jobs only).
    pub carbon_g: f64,
}

/// Sentinel ρ recorded when the policy deliberately idled a non-empty queue
/// (no marginal throughput qualifies: with `p ≤ 1`, a threshold above 1
/// excludes every job).
pub const RHO_IDLE: f64 = 1.01;

/// §Perf: the engine's slot history as structure-of-arrays — one column per
/// [`SlotRecord`] field, with the inline queue-length arrays flattened at
/// stride [`MAX_QUEUES`] (slot `s` occupies `s*MAX_QUEUES ..
/// (s+1)*MAX_QUEUES`). The step loop appends to contiguous arrays, and
/// live consumers (the coordinator's stats, the zero-alloc harness) scan a
/// single column instead of striding a struct array.
#[derive(Debug, Clone, Default)]
pub struct SlotColumns {
    pub t: Vec<u32>,
    pub ci: Vec<f64>,
    pub provisioned: Vec<u32>,
    pub used: Vec<u32>,
    pub rho: Vec<f64>,
    /// Flattened per-queue active-job counts, stride [`MAX_QUEUES`].
    pub queue_lengths: Vec<u32>,
    pub mean_elasticity: Vec<f64>,
    pub energy_kwh: Vec<f64>,
    pub carbon_g: Vec<f64>,
}

impl SlotColumns {
    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    fn reserve(&mut self, additional: usize) {
        self.t.reserve(additional);
        self.ci.reserve(additional);
        self.provisioned.reserve(additional);
        self.used.reserve(additional);
        self.rho.reserve(additional);
        self.queue_lengths.reserve(additional * MAX_QUEUES);
        self.mean_elasticity.reserve(additional);
        self.energy_kwh.reserve(additional);
        self.carbon_g.reserve(additional);
    }

    fn push(&mut self, r: &SlotRecord) {
        debug_assert!(r.t <= u32::MAX as usize, "slot index exceeds u32");
        self.t.push(r.t as u32);
        self.ci.push(r.ci);
        self.provisioned.push(r.provisioned as u32);
        self.used.push(r.used as u32);
        self.rho.push(r.rho);
        for &q in &r.queue_lengths {
            self.queue_lengths.push(q as u32);
        }
        self.mean_elasticity.push(r.mean_elasticity);
        self.energy_kwh.push(r.energy_kwh);
        self.carbon_g.push(r.carbon_g);
    }

    /// Rebuild the record vector (run teardown — not on the step path).
    pub fn materialize(&self) -> Vec<SlotRecord> {
        (0..self.len())
            .map(|s| {
                let mut queue_lengths = [0usize; MAX_QUEUES];
                let flat = &self.queue_lengths[s * MAX_QUEUES..(s + 1) * MAX_QUEUES];
                for (q, &v) in queue_lengths.iter_mut().zip(flat) {
                    *q = v as usize;
                }
                SlotRecord {
                    t: self.t[s] as usize,
                    ci: self.ci[s],
                    provisioned: self.provisioned[s] as usize,
                    used: self.used[s] as usize,
                    rho: self.rho[s],
                    queue_lengths,
                    mean_elasticity: self.mean_elasticity[s],
                    energy_kwh: self.energy_kwh[s],
                    carbon_g: self.carbon_g[s],
                }
            })
            .collect()
    }
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub metrics: RunMetrics,
    pub outcomes: Vec<JobOutcome>,
    pub slots: Vec<SlotRecord>,
    /// Cluster-level overheads (boot energy) folded into `metrics` totals.
    pub overhead_energy_kwh: f64,
    pub overhead_carbon_g: f64,
}

impl SimResult {
    /// Bit-exact fingerprint of the run: headline metrics as raw f64 bits
    /// plus an FNV-1a digest over every slot record. Two runs produce the
    /// same fingerprint iff the engine produced bitwise-identical output —
    /// the golden-determinism tests pin these across refactors.
    pub fn fingerprint(&self) -> String {
        use crate::util::hash::{fold, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        for s in &self.slots {
            h = fold(h, &(s.t as u64).to_le_bytes());
            h = fold(h, &(s.provisioned as u64).to_le_bytes());
            h = fold(h, &(s.used as u64).to_le_bytes());
            h = fold(h, &s.rho.to_bits().to_le_bytes());
            h = fold(h, &s.ci.to_bits().to_le_bytes());
            h = fold(h, &s.energy_kwh.to_bits().to_le_bytes());
            h = fold(h, &s.carbon_g.to_bits().to_le_bytes());
            h = fold(h, &s.mean_elasticity.to_bits().to_le_bytes());
            for &q in &s.queue_lengths {
                h = fold(h, &(q as u64).to_le_bytes());
            }
        }
        for o in &self.outcomes {
            h = fold(h, &(o.id as u64).to_le_bytes());
            h = fold(h, &(o.completion as u64).to_le_bytes());
            h = fold(h, &o.energy_kwh.to_bits().to_le_bytes());
            h = fold(h, &o.carbon_g.to_bits().to_le_bytes());
            h = fold(h, &(o.rescales as u64).to_le_bytes());
        }
        let m = &self.metrics;
        format!(
            "{:016x}-{:016x}-{}-{}-{}-{:016x}-{:016x}",
            m.carbon_g.to_bits(),
            m.energy_kwh.to_bits(),
            m.completed,
            m.unfinished,
            m.violations,
            m.mean_delay_hours.to_bits(),
            h
        )
    }
}

/// Engine configuration shared by the batch simulator and the coordinator.
#[derive(Debug, Clone)]
pub struct Simulator {
    /// Maximum cluster capacity M.
    pub max_capacity: usize,
    pub energy: EnergyModel,
    pub num_queues: usize,
    /// Trace horizon in hours (utilization is reported over this window; the
    /// run itself continues until drain).
    pub horizon: usize,
    /// Hard cap on extra drain slots after the horizon (guards against a
    /// policy that never schedules).
    pub max_drain_slots: usize,
}

/// `JobColumns::flags` bit: the job has run at least one slot.
const STARTED: u8 = 1;
/// `JobColumns::flags` bit: the job completed (its columns are tombstones).
const DONE: u8 = 2;

/// Internal per-job runtime state, structure-of-arrays (§Perf): the advance
/// loop reads and writes parallel `f64`/`u32` columns indexed by dense job
/// id instead of striding a struct array, so each field access touches one
/// contiguous allocation.
#[derive(Debug, Default)]
struct JobColumns {
    /// Remaining work in base-hours.
    remaining: Vec<f64>,
    /// Allocation in the previous slot (0 = suspended/queued).
    prev_alloc: Vec<u32>,
    energy_kwh: Vec<f64>,
    carbon_g: Vec<f64>,
    rescales: Vec<u32>,
    /// Status bits: [`STARTED`] | [`DONE`].
    flags: Vec<u8>,
}

impl JobColumns {
    fn push_job(&mut self, work: f64) {
        self.remaining.push(work);
        self.prev_alloc.push(0);
        self.energy_kwh.push(0.0);
        self.carbon_g.push(0.0);
        self.rescales.push(0);
        self.flags.push(0);
    }

    fn len(&self) -> usize {
        self.flags.len()
    }
}

/// Reusable scratch for [`sanitize`] (§Perf: one allocation-free sanitize
/// pass per slot instead of a fresh `HashMap` + vectors).
#[derive(Debug, Default)]
struct SanitizeScratch {
    /// Per-view allocation — the sanitize output, aligned with the views.
    alloc: Vec<usize>,
    /// Dense job-id → view-index map. Entries go stale across slots and are
    /// validated against the live views on lookup (ids are dense submission
    /// indices, so the table is bounded by the job count).
    idx_of: Vec<usize>,
    /// Trim-loop victim heap: `Reverse((key, view index, alloc at push))`.
    /// Entries are lazily invalidated: a popped entry whose recorded
    /// allocation no longer matches is skipped (its job was re-pushed with
    /// the updated key when it changed).
    heap: BinaryHeap<Reverse<(u128, usize, usize)>>,
}

/// A slot crash whose victims have not all resumed (or completed) yet —
/// the engine tracks these to measure per-fault recovery time.
#[derive(Debug, Clone)]
struct OpenCrash {
    at: usize,
    repair_slots: usize,
    victims: Vec<usize>,
}

/// The stepping core: job state + accounting, advanced one slot at a time.
pub struct ClusterEngine {
    cfg: Simulator,
    jobs: Vec<Job>,
    /// Columnar per-job runtime state (index = dense job id).
    state: JobColumns,
    outcomes: Vec<JobOutcome>,
    /// Columnar slot history; `last` holds the materialized most recent
    /// record so `step` can keep returning `&SlotRecord`.
    slot_cols: SlotColumns,
    last: SlotRecord,
    prev_capacity: usize,
    prev_used: usize,
    overhead_energy: f64,
    overhead_carbon: f64,
    /// Completions in the trailing 24 slots: (slot, violated).
    recent: VecDeque<(usize, bool)>,
    active_jobs: usize,
    /// Not-yet-arrived job indices, sorted by (arrival, id) descending so
    /// the next due arrival pops from the back.
    waiting: Vec<usize>,
    /// Arrived, uncompleted job indices in ascending id order — the view
    /// order every policy sees. Completions compact it in place (order
    /// preserved, so results stay bitwise identical to the full scan).
    active: Vec<usize>,
    /// Arrived jobs still gated on uncompleted dependency parents
    /// ([`Job::deps`]), ascending id order. Invisible to the policy until
    /// released; always empty for flat (zero-edge) workloads.
    blocked: Vec<usize>,
    /// Slot each job became eligible to run (index = dense id): its arrival,
    /// unless a parent completion released it later (then that slot + 1).
    eligible_at: Vec<u32>,
    /// True once any registered job carries dependency edges. Every DAG hook
    /// below guards on this, so flat traces execute the exact pre-DAG
    /// instruction sequence (bitwise-identical, allocation-free).
    has_deps: bool,
    /// Recycled policy-view buffer; always empty between steps, only its
    /// allocation is reused (see the lifetime note in `step`).
    views_buf: Vec<JobView<'static>>,
    /// Columnar mirror of the views, refilled each step (clear+push keeps
    /// the capacity, so steady-state slots allocate nothing).
    cols: JobViewCols,
    /// Recycled policy decision (capacity + alloc buffer).
    decision: Decision,
    scratch: SanitizeScratch,
    /// Injected fault schedule (empty = no faults; see [`crate::faults`]).
    /// Every fault hook below guards on `plan.is_empty()`, so the empty
    /// plan executes the exact pre-fault instruction sequence.
    plan: FaultPlan,
    /// Crashes whose victims have not all resumed or completed yet.
    open_crashes: Vec<OpenCrash>,
    /// Fault bookkeeping surfaced through `RunMetrics`.
    restarts: u64,
    lost_work_hours: f64,
    recovery_slots: Vec<f64>,
}

impl ClusterEngine {
    pub fn new(cfg: Simulator) -> Self {
        assert!(
            cfg.num_queues <= MAX_QUEUES,
            "num_queues {} exceeds MAX_QUEUES {MAX_QUEUES}",
            cfg.num_queues
        );
        let prev_capacity = cfg.max_capacity;
        ClusterEngine {
            cfg,
            jobs: vec![],
            state: JobColumns::default(),
            outcomes: vec![],
            slot_cols: SlotColumns::default(),
            last: SlotRecord::default(),
            prev_capacity,
            prev_used: 0,
            overhead_energy: 0.0,
            overhead_carbon: 0.0,
            recent: VecDeque::new(),
            active_jobs: 0,
            waiting: vec![],
            active: vec![],
            blocked: vec![],
            eligible_at: vec![],
            has_deps: false,
            views_buf: vec![],
            cols: JobViewCols::default(),
            decision: Decision::default(),
            scratch: SanitizeScratch::default(),
            plan: FaultPlan::none(),
            open_crashes: vec![],
            restarts: 0,
            lost_work_hours: 0.0,
            recovery_slots: vec![],
        }
    }

    /// Install a fault schedule (before stepping). The default is the
    /// empty plan, which injects nothing.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.plan = plan;
    }

    /// Register a job. `job.id` must equal its submission index.
    pub fn add_job(&mut self, job: Job) {
        assert_eq!(job.id, self.jobs.len(), "job ids must be dense submission indices");
        for &p in &job.deps {
            assert!(p < job.id, "dep {p} of job {} is not an earlier job", job.id);
        }
        let idx = self.jobs.len();
        let arrival = job.arrival;
        if !job.deps.is_empty() {
            self.has_deps = true;
        }
        self.jobs.push(job);
        self.state.push_job(self.jobs.last().unwrap().work());
        self.eligible_at.push(arrival as u32);
        self.active_jobs += 1;
        // Keep `waiting` sorted by (arrival, id) descending; the next due
        // arrival is at the back. Submission outside the step loop, so the
        // O(n) insert is off the hot path.
        let jobs = &self.jobs;
        let pos = self.waiting.partition_point(|&j| (jobs[j].arrival, j) > (arrival, idx));
        self.waiting.insert(pos, idx);
    }

    /// Pre-size the record and scratch buffers so a run of `slots` steps
    /// over the registered jobs allocates nothing in steady state.
    pub fn reserve(&mut self, slots: usize) {
        let n = self.jobs.len();
        self.slot_cols.reserve(slots);
        self.outcomes.reserve(n);
        self.recent.reserve(n + 1);
        self.active.reserve(n);
        if self.has_deps {
            self.blocked.reserve(n);
        }
        self.views_buf.reserve(n);
        self.cols.reserve(n);
        self.decision.alloc.reserve(n);
        self.scratch.alloc.reserve(n);
        self.scratch.idx_of.reserve(n);
        self.scratch.heap.reserve(n + 1);
    }

    /// Jobs not yet completed (arrived or not).
    pub fn pending_jobs(&self) -> usize {
        self.active_jobs
    }

    pub fn outcomes(&self) -> &[JobOutcome] {
        &self.outcomes
    }

    /// The columnar slot history (one entry per completed step).
    pub fn slot_columns(&self) -> &SlotColumns {
        &self.slot_cols
    }

    /// Number of recorded slots.
    pub fn num_slots(&self) -> usize {
        self.slot_cols.len()
    }

    /// The most recent slot record, if any step has run.
    pub fn last_slot(&self) -> Option<&SlotRecord> {
        (!self.slot_cols.is_empty()).then_some(&self.last)
    }

    /// Advance one slot. Returns the slot record.
    pub fn step(
        &mut self,
        t: usize,
        forecaster: &Forecaster,
        policy: &mut dyn Policy,
    ) -> &SlotRecord {
        // Admit due arrivals from the back of the waiting list, then restore
        // ascending-id view order (identical to the historical full scan).
        let mut admitted = false;
        while let Some(&j) = self.waiting.last() {
            if self.jobs[j].arrival > t {
                break;
            }
            self.waiting.pop();
            if self.has_deps
                && self.jobs[j].deps.iter().any(|&p| self.state.flags[p] & DONE == 0)
            {
                // Dependency-gated: invisible to the policy until every
                // parent completes (see `release_ready_children`).
                let pos = self.blocked.partition_point(|&b| b < j);
                self.blocked.insert(pos, j);
            } else {
                self.active.push(j);
                admitted = true;
            }
        }
        if admitted {
            self.active.sort_unstable();
        }

        // Fault injection: crash onsets suspend victims through the
        // ordinary checkpoint path, and in-repair crashes shrink the
        // usable capacity. Guarded so the empty plan touches nothing.
        let mut eff_max = self.cfg.max_capacity;
        if !self.plan.is_empty() {
            self.crash_onset(t);
            eff_max =
                self.cfg.max_capacity.saturating_sub(self.plan.capacity_down_at(t)).max(1);
        }

        if self.active.is_empty() {
            if !self.plan.is_empty() {
                self.resolve_crashes(t);
            }
            self.prev_used = 0;
            self.last = SlotRecord {
                t,
                ci: forecaster.truth().at(t),
                provisioned: 0,
                used: 0,
                rho: 1.0,
                queue_lengths: [0; MAX_QUEUES],
                mean_elasticity: 0.0,
                energy_kwh: 0.0,
                carbon_g: 0.0,
            };
            self.slot_cols.push(&self.last);
            return &self.last;
        }

        while let Some(&(ct, _)) = self.recent.front() {
            if ct + 24 <= t {
                self.recent.pop_front();
            } else {
                break;
            }
        }
        let recent_violation_rate = if self.recent.is_empty() {
            0.0
        } else {
            self.recent.iter().filter(|(_, v)| *v).count() as f64 / self.recent.len() as f64
        };

        // Recycle the view buffer's allocation. `views_buf` is stored with a
        // `'static` placeholder lifetime and is always empty between steps;
        // `Vec` is covariant, so taking it at the local (shorter) lifetime
        // is a plain coercion.
        let mut views: Vec<JobView<'_>> = std::mem::take(&mut self.views_buf);
        debug_assert!(views.is_empty());
        self.cols.clear();
        for &i in &self.active {
            let jv = JobView {
                job: &self.jobs[i],
                remaining: self.state.remaining[i],
                prev_alloc: self.state.prev_alloc[i] as usize,
                overdue: false,
                eligible_since: self.eligible_at[i] as usize,
            };
            let overdue = jv.slack_left(t) <= 0.0;
            self.cols.push(&self.jobs[i], jv.remaining, jv.prev_alloc, overdue, jv.eligible_since);
            views.push(JobView { overdue, ..jv });
        }

        let ctx = SlotCtx {
            t,
            jobs: &views,
            cols: &self.cols,
            forecaster,
            max_capacity: eff_max,
            num_queues: self.cfg.num_queues,
            prev_capacity: self.prev_capacity,
            prev_used: self.prev_used,
            recent_violation_rate,
        };
        let queue_lengths = ctx.queue_lengths();
        let mean_elasticity = ctx.mean_elasticity();
        policy.decide_into(&ctx, &mut self.decision);

        let provisioned =
            sanitize(eff_max, &self.decision, &views, &self.cols, &mut self.scratch);

        // --- Advance jobs ---
        let ci = forecaster.truth().at(t);
        let mut slot_energy = 0.0f64;
        let mut slot_carbon = 0.0f64;
        let mut used = 0usize;
        let mut rho: f64 = f64::INFINITY;
        let mut any_ran = false;
        let mut completed_any = false;

        // Index-driven advance over the job columns: each field access hits
        // one contiguous array, with `i` the dense job id.
        for (idx, &i) in self.active.iter().enumerate() {
            let k = self.scratch.alloc[idx];
            let job = &self.jobs[i];
            if k == 0 {
                // Suspension of a running job is a checkpoint event.
                if self.state.prev_alloc[i] > 0 {
                    self.state.rescales[i] += 1;
                }
                self.state.prev_alloc[i] = 0;
                continue;
            }
            any_ran = true;
            used += k;
            rho = rho.min(job.marginal(k));

            let rate = job.rate(k);
            let mut penalty = 0.0;
            let prev = self.state.prev_alloc[i] as usize;
            if self.state.flags[i] & STARTED != 0 && prev != k && prev > 0 {
                self.state.rescales[i] += 1;
                penalty = self.cfg.energy.ckpt_progress_penalty(rate);
            }
            self.state.flags[i] |= STARTED;
            let progress = (rate - penalty).max(0.0);
            let remaining = self.state.remaining[i];
            let (fraction, finished) = if remaining <= progress {
                ((remaining + penalty) / rate, true)
            } else {
                (1.0, false)
            };
            let e = self.cfg.energy.job_energy_kwh(job, k, fraction.min(1.0));
            self.state.energy_kwh[i] += e;
            self.state.carbon_g[i] += e * ci;
            slot_energy += e;
            slot_carbon += e * ci;

            if finished {
                self.state.remaining[i] = 0.0;
                self.state.flags[i] |= DONE;
                self.state.prev_alloc[i] = 0;
                self.active_jobs -= 1;
                let outcome = JobOutcome {
                    id: job.id,
                    arrival: job.arrival,
                    completion: t,
                    length_hours: job.length_hours,
                    slack_hours: job.slack_hours,
                    energy_kwh: self.state.energy_kwh[i],
                    carbon_g: self.state.carbon_g[i],
                    rescales: self.state.rescales[i] as usize,
                };
                self.recent.push_back((t, outcome.violated_slo()));
                policy.on_complete(job.id, t);
                self.outcomes.push(outcome);
                completed_any = true;
            } else {
                self.state.remaining[i] -= progress;
                self.state.prev_alloc[i] = k as u32;
            }
        }
        if completed_any {
            let flags = &self.state.flags;
            self.active.retain(|&i| flags[i] & DONE == 0);
        }
        if self.has_deps && completed_any && !self.blocked.is_empty() {
            self.release_ready_children(t);
        }
        if !self.plan.is_empty() {
            self.resolve_crashes(t);
        }

        // Boot energy for newly provisioned servers (3–5 min lag, §6.8).
        if provisioned > self.prev_capacity {
            let boot = self.cfg.energy.boot_energy_kwh(provisioned - self.prev_capacity);
            self.overhead_energy += boot;
            self.overhead_carbon += boot * ci;
        }
        self.prev_capacity = provisioned;
        self.prev_used = used;

        let rho = if any_ran {
            rho
        } else if views.is_empty() {
            1.0
        } else {
            RHO_IDLE
        };

        // Store the emptied view buffer back for the next step. SAFETY: the
        // buffer is cleared first, so no reference tied to this step's
        // borrow of `self.jobs` survives; only the raw allocation is
        // recycled, and `Vec<JobView<'a>>` and `Vec<JobView<'static>>` are
        // layout-identical (they differ only in the lifetime parameter).
        views.clear();
        #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
        {
            self.views_buf =
                unsafe { std::mem::transmute::<Vec<JobView<'_>>, Vec<JobView<'static>>>(views) };
        }

        self.last = SlotRecord {
            t,
            ci,
            provisioned,
            used,
            rho,
            queue_lengths,
            mean_elasticity,
            energy_kwh: slot_energy,
            carbon_g: slot_carbon,
        };
        self.slot_cols.push(&self.last);
        &self.last
    }

    /// Fault hook: crashes whose onset is slot `t` suspend enough running
    /// jobs (latest admissions first, a deterministic order) to free the
    /// crashed servers. Victims go through the ordinary checkpoint path —
    /// a rescale event plus suspension — and additionally lose up to the
    /// crash's `rework_hours` of completed progress.
    fn crash_onset(&mut self, t: usize) {
        for ci in 0..self.plan.crashes.len() {
            let c = self.plan.crashes[ci];
            if c.at != t {
                continue;
            }
            let mut freed = 0usize;
            let mut victims: Vec<usize> = Vec::new();
            for pos in (0..self.active.len()).rev() {
                if freed >= c.down {
                    break;
                }
                let i = self.active[pos];
                let prev = self.state.prev_alloc[i] as usize;
                if prev == 0 {
                    continue; // already queued; nothing to displace
                }
                freed += prev;
                // Suspend through the existing suspend/resume path: the
                // advance loop sees prev_alloc == 0 and requeues the job.
                self.state.rescales[i] += 1;
                self.state.prev_alloc[i] = 0;
                let done = (self.jobs[i].work() - self.state.remaining[i]).max(0.0);
                let lost = done.min(c.rework_hours);
                self.state.remaining[i] += lost;
                self.lost_work_hours += lost;
                self.restarts += 1;
                victims.push(i);
            }
            self.open_crashes.push(OpenCrash {
                at: c.at,
                repair_slots: c.repair_slots,
                victims,
            });
        }
    }

    /// Fault hook: a crash is recovered once every victim is running again
    /// (or completed) *and* its servers are repaired; the elapsed slots
    /// feed the recovery-time percentiles in [`RunMetrics`].
    fn resolve_crashes(&mut self, t: usize) {
        let mut k = 0;
        while k < self.open_crashes.len() {
            let oc = &self.open_crashes[k];
            let victims_back = oc
                .victims
                .iter()
                .all(|&i| self.state.flags[i] & DONE != 0 || self.state.prev_alloc[i] > 0);
            if victims_back {
                let oc = self.open_crashes.swap_remove(k);
                let recovery = (t - oc.at).max(oc.repair_slots);
                self.recovery_slots.push(recovery as f64);
            } else {
                k += 1;
            }
        }
    }

    /// DAG hook: move blocked jobs whose parents have all completed into
    /// the active set. A child released by a completion in slot `t` is
    /// eligible from slot `t + 1` — it never runs in (or before) the slot
    /// its last parent finished in. A crashed parent is simply not DONE
    /// (completion is permanent; crashes only hit running jobs), so its
    /// children stay gated here until the reworked parent completes.
    fn release_ready_children(&mut self, t: usize) {
        let flags = &self.state.flags;
        let jobs = &self.jobs;
        let eligible_at = &mut self.eligible_at;
        let active = &mut self.active;
        let mut released = false;
        self.blocked.retain(|&j| {
            let ready = jobs[j].deps.iter().all(|&p| flags[p] & DONE != 0);
            if ready {
                eligible_at[j] = (t + 1) as u32;
                active.push(j);
                released = true;
            }
            !ready
        });
        if released {
            active.sort_unstable();
        }
    }

    /// Finalize into a [`SimResult`].
    pub fn finish(self, policy_name: &str) -> SimResult {
        let unfinished = self.state.flags.iter().filter(|&&f| f & DONE == 0).count();
        debug_assert_eq!(self.state.len(), self.jobs.len());
        // The `used` column doubles as the usage-per-slot series the
        // metrics need (teardown-time widening copy, off the step path).
        let usage_per_slot: Vec<usize> =
            self.slot_cols.used.iter().map(|&u| u as usize).collect();
        let mut metrics = RunMetrics::from_outcomes(
            policy_name,
            &self.outcomes,
            unfinished,
            &usage_per_slot,
            self.cfg.max_capacity,
            self.cfg.horizon,
        );
        metrics.energy_kwh += self.overhead_energy;
        metrics.carbon_g += self.overhead_carbon;
        metrics.restarts = self.restarts;
        metrics.lost_work_hours = self.lost_work_hours;
        // Crashes still open at drain never recovered within the run;
        // charge them the full span to the last stepped slot.
        let mut recovery = self.recovery_slots;
        if !self.open_crashes.is_empty() {
            let end_t = self.slot_cols.t.last().copied().unwrap_or(0) as usize;
            for oc in &self.open_crashes {
                recovery.push(end_t.saturating_sub(oc.at).max(oc.repair_slots) as f64);
            }
        }
        if !recovery.is_empty() {
            metrics.recovery_p50_slots = stats::percentile(&recovery, 50.0);
            metrics.recovery_p99_slots = stats::percentile(&recovery, 99.0);
        }
        SimResult {
            metrics,
            outcomes: self.outcomes,
            slots: self.slot_cols.materialize(),
            overhead_energy_kwh: self.overhead_energy,
            overhead_carbon_g: self.overhead_carbon,
        }
    }
}

/// Total-order key for a trim victim: `is_base` above a monotone f64→bits
/// map of the marginal throughput, so the heap's minimum is exactly the
/// victim the historical linear scan picked (non-base before base, lowest
/// marginal first; callers add the view index for the first-found tie-break).
fn victim_key(is_base: bool, marginal: f64) -> u128 {
    // Standard total-order trick: positive floats get the sign bit set,
    // negatives are bit-flipped, making the u64 order match the f64 order.
    let b = marginal.to_bits();
    let fbits = if b >> 63 == 0 { b | (1 << 63) } else { !b };
    ((is_base as u128) << 64) | fbits as u128
}

/// Enforce engine invariants on a raw decision:
/// 1. `m_t ≤ M`;
/// 2. every allocation within the job's `[k_min, k_max]`;
/// 3. overdue jobs are force-run at ≥ k_min (paper: run-to-completion once
///    slack is exhausted), even past `m_t`, but never past M;
/// 4. total allocation fits within `max(m_t, forced)`, trimming the
///    lowest-marginal servers first (scaled servers before suspensions).
///
/// Returns the provisioned capacity; the per-active-job allocation (aligned
/// with `views`) is left in `s.alloc`. §Perf: all working state lives in
/// the reusable scratch, and the trim loop pops victims from a lazily
/// invalidated binary heap instead of rescanning every view per trimmed
/// server (O(n·excess) → O((n + excess)·log n)), bitwise-identical to the
/// scan (see `sanitize_matches_reference_on_random_decisions`). The id map
/// fill, clamp, and overdue scans are index loops over the columnar view
/// mirror (`cols`, entry `i` ↔ `views[i]`); `views` is only consulted for
/// the profile-dependent fields (marginal throughput, deadline).
fn sanitize(
    max_capacity: usize,
    decision: &Decision,
    views: &[JobView],
    cols: &JobViewCols,
    s: &mut SanitizeScratch,
) -> usize {
    debug_assert_eq!(views.len(), cols.len());
    let provisioned = decision.capacity.min(max_capacity);
    s.alloc.clear();
    s.alloc.resize(cols.len(), 0);
    // Dense job-id → view-index map. Stale entries from previous slots are
    // fine: every lookup is validated against the live id column.
    let max_id = cols.id.iter().copied().max().unwrap_or(0);
    if s.idx_of.len() <= max_id {
        s.idx_of.resize(max_id + 1, usize::MAX);
    }
    for (i, &id) in cols.id.iter().enumerate() {
        s.idx_of[id] = i;
    }
    for &(id, k) in &decision.alloc {
        let Some(&idx) = s.idx_of.get(id) else { continue };
        if idx >= cols.len() || cols.id[idx] != id {
            continue; // unknown or stale id
        }
        if k > 0 {
            s.alloc[idx] = k.clamp(cols.k_min[idx] as usize, cols.k_max[idx] as usize);
        }
    }
    // Force-run overdue jobs (flag-column scan).
    for (idx, &overdue) in cols.overdue.iter().enumerate() {
        if overdue && s.alloc[idx] == 0 {
            s.alloc[idx] = cols.k_min[idx] as usize;
        }
    }
    let forced: usize =
        cols.overdue.iter().enumerate().filter(|(_, &o)| o).map(|(i, _)| s.alloc[i]).sum();
    let budget = provisioned.max(forced).min(max_capacity);

    // Trim until the allocation fits the budget. Victim: the allocated top
    // server with the lowest marginal throughput. Prefer shrinking scaled
    // jobs; suspend non-overdue base allocations only if nothing is scaled;
    // never shrink an overdue job below k_min.
    let mut total: usize = s.alloc.iter().sum();
    if total > budget {
        s.heap.clear();
        for (idx, v) in views.iter().enumerate() {
            let k = s.alloc[idx];
            if k == 0 {
                continue;
            }
            let is_base = k == cols.k_min[idx] as usize;
            if is_base && cols.overdue[idx] {
                continue; // untouchable
            }
            s.heap.push(Reverse((victim_key(is_base, v.job.marginal(k)), idx, k)));
        }
        while total > budget {
            let Some(Reverse((_, idx, k))) = s.heap.pop() else {
                break; // only overdue base allocations remain
            };
            if s.alloc[idx] != k {
                continue; // stale: this job changed since the entry was pushed
            }
            let k_min = cols.k_min[idx] as usize;
            if k == k_min {
                total -= k;
                s.alloc[idx] = 0;
            } else {
                let nk = k - 1;
                s.alloc[idx] = nk;
                total -= 1;
                let now_base = nk == k_min;
                if nk > 0 && !(now_base && cols.overdue[idx]) {
                    s.heap.push(Reverse((
                        victim_key(now_base, views[idx].job.marginal(nk)),
                        idx,
                        nk,
                    )));
                }
            }
        }
    }
    // M is a hard physical limit: if overdue base allocations alone exceed
    // it, defer the ones with the latest deadlines (they are already late;
    // capacity simply does not exist).
    while total > max_capacity {
        let victim = views
            .iter()
            .enumerate()
            .filter(|(i, _)| s.alloc[*i] > 0)
            .max_by_key(|(_, v)| v.job.deadline_slot());
        match victim {
            Some((idx, _)) => {
                total -= s.alloc[idx];
                s.alloc[idx] = 0;
            }
            None => break,
        }
    }
    provisioned
}

impl Simulator {
    pub fn new(
        max_capacity: usize,
        energy: EnergyModel,
        num_queues: usize,
        horizon: usize,
    ) -> Self {
        Simulator { max_capacity, energy, num_queues, horizon, max_drain_slots: 4096 }
    }

    /// Batch driver: run `policy` over `jobs` until every job drains.
    pub fn run(&self, jobs: &[Job], forecaster: &Forecaster, policy: &mut dyn Policy) -> SimResult {
        self.run_with_plan(jobs, forecaster, policy, &FaultPlan::none())
    }

    /// Batch driver with an injected fault schedule. The empty plan is
    /// bitwise identical to [`Simulator::run`]; a non-empty plan replays
    /// the same failure history on every run with the same inputs.
    pub fn run_with_plan(
        &self,
        jobs: &[Job],
        forecaster: &Forecaster,
        policy: &mut dyn Policy,
        plan: &FaultPlan,
    ) -> SimResult {
        let mut engine = ClusterEngine::new(self.clone());
        engine.set_fault_plan(plan.clone());
        for job in jobs {
            engine.add_job(job.clone());
        }
        let last_arrival = jobs.iter().map(|j| j.arrival).max().unwrap_or(0);
        let t_end = last_arrival + self.horizon + self.max_drain_slots;
        // Runs normally drain shortly after the horizon; the record vectors
        // grow geometrically past this if a policy stalls into drain slots.
        engine.reserve(last_arrival + self.horizon + 1);
        let mut t = 0usize;
        while engine.pending_jobs() > 0 && t < t_end {
            engine.step(t, forecaster, policy);
            t += 1;
        }
        let mut result = engine.finish(policy.name());
        let d = policy.degradation();
        result.metrics.degraded_stale = d.stale;
        result.metrics.degraded_fallback = d.fallback;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::trace::CarbonTrace;
    use crate::config::Hardware;
    use crate::workload::profile::ScalingProfile;

    fn flat_forecaster(hours: usize, ci: f64) -> Forecaster {
        Forecaster::perfect(CarbonTrace::new("flat", vec![ci; hours]))
    }

    fn job(id: usize, arrival: usize, length: f64, slack: f64, k_max: usize) -> Job {
        Job {
            id,
            workload: "N-body(N=100k)",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max,
            profile: ScalingProfile::from_comm_ratio(0.02, k_max),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn sim(cap: usize, horizon: usize) -> Simulator {
        Simulator::new(cap, EnergyModel::for_hardware(Hardware::Cpu), 3, horizon)
    }

    /// Policy: run everything at k_min, full capacity.
    struct RunAll;
    impl Policy for RunAll {
        fn name(&self) -> &'static str {
            "run-all"
        }
        fn decide(&mut self, ctx: &SlotCtx) -> Decision {
            Decision {
                capacity: ctx.max_capacity,
                alloc: ctx.jobs.iter().map(|v| (v.job.id, v.job.k_min)).collect(),
            }
        }
    }

    /// Policy: never schedule anything (tests force-run).
    struct NeverRun;
    impl Policy for NeverRun {
        fn name(&self) -> &'static str {
            "never"
        }
        fn decide(&mut self, _ctx: &SlotCtx) -> Decision {
            Decision { capacity: 0, alloc: vec![] }
        }
    }

    /// Policy: scale everything to the max.
    struct ScaleAll;
    impl Policy for ScaleAll {
        fn name(&self) -> &'static str {
            "scale-all"
        }
        fn decide(&mut self, ctx: &SlotCtx) -> Decision {
            Decision {
                capacity: ctx.max_capacity,
                alloc: ctx.jobs.iter().map(|v| (v.job.id, v.job.k_max)).collect(),
            }
        }
    }

    #[test]
    fn single_job_runs_to_completion() {
        let jobs = vec![job(0, 0, 3.0, 6.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut RunAll);
        assert_eq!(r.metrics.completed, 1);
        assert_eq!(r.metrics.unfinished, 0);
        // 3 hours at 40 W = 0.12 kWh → 12 g at CI 100.
        assert!((r.metrics.energy_kwh - 0.12).abs() < 1e-6, "{}", r.metrics.energy_kwh);
        assert!((r.metrics.carbon_g - 12.0).abs() < 1e-4);
        assert_eq!(r.outcomes[0].completion, 2);
        assert_eq!(r.outcomes[0].rescales, 0);
    }

    #[test]
    fn never_run_policy_is_forced_at_deadline() {
        let jobs = vec![job(0, 0, 2.0, 3.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut NeverRun);
        assert_eq!(r.metrics.completed, 1);
        let o = &r.outcomes[0];
        // deadline slot = 0 + ceil(2+3) = 5; forced when slack_left ≤ 0
        // (t=3: 5−3−2=0) → runs slots 3,4 → completes at 4, inside SLO.
        assert_eq!(o.completion, 4);
        assert!(!o.violated_slo());
    }

    #[test]
    fn scaling_speeds_up_completion() {
        let jobs = vec![job(0, 0, 4.0, 6.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let base = sim(10, 24).run(&jobs, &f, &mut RunAll);
        let scaled = sim(10, 24).run(&jobs, &f, &mut ScaleAll);
        assert!(scaled.outcomes[0].completion < base.outcomes[0].completion);
        // Scaling uses more energy (sub-linear throughput).
        assert!(scaled.metrics.energy_kwh > base.metrics.energy_kwh);
    }

    #[test]
    fn capacity_cap_is_enforced() {
        // 5 jobs, capacity 3, all want k_min=1 → at most 3 run per slot.
        let jobs: Vec<Job> = (0..5).map(|i| job(i, 0, 2.0, 24.0, 4)).collect();
        let f = flat_forecaster(200, 100.0);
        let r = sim(3, 48).run(&jobs, &f, &mut RunAll);
        assert_eq!(r.metrics.completed, 5);
        assert!(r.slots.iter().all(|s| s.used <= 3), "capacity exceeded");
    }

    #[test]
    fn trimming_prefers_scaled_servers() {
        // 2 jobs want k=4 each, capacity 5 → trim to fit; both should keep
        // at least k_min.
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0, 4)).collect();
        let f = flat_forecaster(200, 100.0);
        let r = sim(5, 48).run(&jobs, &f, &mut ScaleAll);
        let first = &r.slots[0];
        assert!(first.used <= 5);
        assert!(first.used >= 2, "both jobs should run at least base scale");
    }

    #[test]
    fn rescale_counted_and_penalized() {
        struct Flip(bool);
        impl Policy for Flip {
            fn name(&self) -> &'static str {
                "flip"
            }
            fn decide(&mut self, ctx: &SlotCtx) -> Decision {
                self.0 = !self.0;
                let k = if self.0 { 1 } else { 4 };
                Decision {
                    capacity: ctx.max_capacity,
                    alloc: ctx.jobs.iter().map(|v| (v.job.id, k)).collect(),
                }
            }
        }
        let jobs = vec![job(0, 0, 6.0, 24.0, 4)];
        let f = flat_forecaster(200, 100.0);
        let r = sim(10, 48).run(&jobs, &f, &mut Flip(false));
        assert!(r.outcomes[0].rescales >= 2, "rescales {}", r.outcomes[0].rescales);
    }

    #[test]
    fn slot_records_capture_rho() {
        let jobs = vec![job(0, 0, 2.0, 6.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut ScaleAll);
        // Scaled to k=4 → rho = marginal(4) < 1.
        assert!(r.slots[0].rho < 1.0);
        let r2 = sim(10, 24).run(&jobs, &f, &mut RunAll);
        assert_eq!(r2.slots[0].rho, 1.0);
        // NeverRun with queued jobs → RHO_IDLE until forced.
        let r3 = sim(10, 24).run(&jobs, &f, &mut NeverRun);
        assert_eq!(r3.slots[0].rho, RHO_IDLE);
    }

    #[test]
    fn arrivals_respected() {
        let jobs = vec![job(0, 5, 2.0, 6.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut RunAll);
        assert!(r.slots[..5].iter().all(|s| s.used == 0));
        assert_eq!(r.outcomes[0].completion, 6);
    }

    #[test]
    fn queue_lengths_in_slot_records() {
        let mut j0 = job(0, 0, 2.0, 6.0, 4);
        j0.queue = 0;
        let mut j1 = job(1, 0, 2.0, 6.0, 4);
        j1.queue = 2;
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&[j0, j1], &f, &mut RunAll);
        assert_eq!(r.slots[0].queue_lengths[..3], [1, 0, 1]);
        assert!(r.slots[0].queue_lengths[3..].iter().all(|&l| l == 0));
    }

    #[test]
    fn partial_final_slot_energy() {
        // 1.5 h job at k_min: second slot only half-charged.
        let jobs = vec![job(0, 0, 1.5, 6.0, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut RunAll);
        assert!((r.metrics.energy_kwh - 0.06).abs() < 1e-6, "{}", r.metrics.energy_kwh);
    }

    #[test]
    fn drain_cap_prevents_infinite_loop() {
        let mut s = sim(10, 24);
        s.max_drain_slots = 8;
        let jobs = vec![job(0, 0, 2.0, 1e6, 4)];
        let f = flat_forecaster(100, 100.0);
        let r = s.run(&jobs, &f, &mut NeverRun);
        assert_eq!(r.metrics.unfinished, 1);
    }

    /// The pre-optimization sanitize pass, kept verbatim as the semantic
    /// reference: the heap-based rewrite must match it bitwise on any input.
    fn reference_sanitize(
        max_capacity: usize,
        decision: &Decision,
        views: &[JobView],
    ) -> (usize, Vec<usize>) {
        let provisioned = decision.capacity.min(max_capacity);
        let mut alloc = vec![0usize; views.len()];
        let index_of: std::collections::HashMap<usize, usize> =
            views.iter().enumerate().map(|(i, v)| (v.job.id, i)).collect();
        for &(id, k) in &decision.alloc {
            if let Some(&idx) = index_of.get(&id) {
                if k > 0 {
                    alloc[idx] = k.clamp(views[idx].job.k_min, views[idx].job.k_max);
                }
            }
        }
        for (idx, v) in views.iter().enumerate() {
            if v.overdue && alloc[idx] == 0 {
                alloc[idx] = v.job.k_min;
            }
        }
        let forced: usize =
            views.iter().enumerate().filter(|(_, v)| v.overdue).map(|(i, _)| alloc[i]).sum();
        let budget = provisioned.max(forced).min(max_capacity);
        let mut total: usize = alloc.iter().sum();
        while total > budget {
            let mut best: Option<(usize, f64, bool)> = None;
            for (idx, v) in views.iter().enumerate() {
                let k = alloc[idx];
                if k == 0 {
                    continue;
                }
                let is_base = k == v.job.k_min;
                if is_base && v.overdue {
                    continue;
                }
                let m = v.job.marginal(k);
                let candidate = (idx, m, is_base);
                best = match best {
                    None => Some(candidate),
                    Some((_, bm, bbase)) => {
                        if (is_base, m) < (bbase, bm) {
                            Some(candidate)
                        } else {
                            best
                        }
                    }
                };
            }
            match best {
                Some((idx, _, is_base)) => {
                    if is_base {
                        total -= alloc[idx];
                        alloc[idx] = 0;
                    } else {
                        alloc[idx] -= 1;
                        total -= 1;
                    }
                }
                None => break,
            }
        }
        while total > max_capacity {
            let victim = views
                .iter()
                .enumerate()
                .filter(|(i, _)| alloc[*i] > 0)
                .max_by_key(|(_, v)| v.job.deadline_slot());
            match victim {
                Some((idx, _)) => {
                    total -= alloc[idx];
                    alloc[idx] = 0;
                }
                None => break,
            }
        }
        (provisioned, alloc)
    }

    #[test]
    fn sanitize_matches_reference_on_random_decisions() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FF_EE42);
        // One scratch across every case, so stale id-map entries and heap
        // reuse are exercised the way the engine exercises them.
        let mut scratch = SanitizeScratch::default();
        for case in 0..400 {
            let n = 1 + rng.below(9);
            let jobs: Vec<Job> = (0..n)
                .map(|i| {
                    let k_max = 1 + rng.below(5);
                    let mut j = job(i, 0, 1.0 + rng.range(0.0, 5.0), rng.range(0.0, 6.0), k_max);
                    j.profile = ScalingProfile::from_comm_ratio(rng.range(0.0, 0.3), k_max);
                    j
                })
                .collect();
            let views: Vec<JobView> = jobs
                .iter()
                .map(|j| JobView {
                    job: j,
                    remaining: rng.range(0.1, j.work().max(0.2)),
                    prev_alloc: rng.below(j.k_max + 1),
                    overdue: rng.chance(0.3),
                    eligible_since: j.arrival,
                })
                .collect();
            // Random decision, including duplicate, unknown, and huge ids.
            let n_alloc = rng.below(2 * n + 3);
            let alloc: Vec<(usize, usize)> = (0..n_alloc)
                .map(|_| {
                    let id = if rng.chance(0.1) { usize::MAX } else { rng.below(n + 3) };
                    (id, rng.below(8))
                })
                .collect();
            let decision = Decision { capacity: rng.below(14), alloc };
            let max_capacity = 1 + rng.below(10);
            let cols = JobViewCols::from_views(&views);
            let provisioned = sanitize(max_capacity, &decision, &views, &cols, &mut scratch);
            let (ref_provisioned, ref_alloc) = reference_sanitize(max_capacity, &decision, &views);
            assert_eq!(provisioned, ref_provisioned, "case {case}: provisioned diverged");
            assert_eq!(scratch.alloc, ref_alloc, "case {case}: allocation diverged");
        }
    }

    /// Property: columnar sanitize == AoS reference under dense marginal
    /// ties — every job shares one scaling profile, so the trim loop's
    /// victim keys collide constantly and only the (is_base, marginal,
    /// view index) tie order separates them.
    #[test]
    fn property_sanitize_ties_match_reference() {
        use crate::util::proptest_lite::{check, Config};
        check(
            "sanitize ties == reference",
            Config { cases: 128, seed: 0x71E5 },
            |rng| {
                let n = 2 + rng.below(8);
                let k_max = 2 + rng.below(3);
                // One shared profile → identical marginals at every k.
                let jobs: Vec<Job> = (0..n).map(|i| job(i, 0, 3.0, 2.0, k_max)).collect();
                let overdue: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
                let alloc: Vec<(usize, usize)> =
                    (0..n).map(|i| (i, rng.below(k_max + 2))).collect();
                let capacity = rng.below(2 * n);
                let max_capacity = 1 + rng.below(n + 2);
                (jobs, overdue, alloc, capacity, max_capacity)
            },
            |(jobs, overdue, alloc, capacity, max_capacity)| {
                let views: Vec<JobView> = jobs
                    .iter()
                    .zip(overdue)
                    .map(|(j, &o)| JobView {
                        job: j,
                        remaining: j.work(),
                        prev_alloc: 0,
                        overdue: o,
                        eligible_since: j.arrival,
                    })
                    .collect();
                let cols = JobViewCols::from_views(&views);
                let decision = Decision { capacity: *capacity, alloc: alloc.clone() };
                let mut scratch = SanitizeScratch::default();
                let got = sanitize(*max_capacity, &decision, &views, &cols, &mut scratch);
                let (want, want_alloc) = reference_sanitize(*max_capacity, &decision, &views);
                if got != want {
                    return Err(format!("provisioned: got {got} want {want}"));
                }
                if scratch.alloc != want_alloc {
                    return Err(format!("alloc: got {:?} want {want_alloc:?}", scratch.alloc));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn engine_incremental_submission() {
        // Coordinator-style use: submit mid-run.
        let f = flat_forecaster(50, 100.0);
        let mut engine = ClusterEngine::new(sim(10, 24));
        engine.add_job(job(0, 0, 2.0, 6.0, 4));
        let mut policy = RunAll;
        engine.step(0, &f, &mut policy);
        let mut late = job(1, 0, 2.0, 6.0, 4);
        late.arrival = 2;
        engine.add_job(late);
        for t in 1..10 {
            engine.step(t, &f, &mut policy);
        }
        let r = engine.finish("run-all");
        assert_eq!(r.metrics.completed, 2);
    }

    #[test]
    fn slot_columns_round_trip_and_last_slot() {
        let f = flat_forecaster(50, 100.0);
        let mut engine = ClusterEngine::new(sim(10, 24));
        assert!(engine.last_slot().is_none());
        engine.add_job(job(0, 0, 2.0, 6.0, 4));
        let mut policy = RunAll;
        for t in 0..4 {
            let rec = engine.step(t, &f, &mut policy).clone();
            let from_last = engine.last_slot().expect("stepped").clone();
            assert_eq!(rec.t, from_last.t);
            assert_eq!(rec.used, from_last.used);
        }
        assert_eq!(engine.num_slots(), 4);
        let cols = engine.slot_columns();
        let records = cols.materialize();
        assert_eq!(records.len(), 4);
        for (s, r) in records.iter().enumerate() {
            assert_eq!(r.t, cols.t[s] as usize);
            assert_eq!(r.used, cols.used[s] as usize);
            assert_eq!(r.rho.to_bits(), cols.rho[s].to_bits());
            let flat = &cols.queue_lengths[s * MAX_QUEUES..(s + 1) * MAX_QUEUES];
            for (q, &v) in r.queue_lengths.iter().zip(flat) {
                assert_eq!(*q, v as usize);
            }
        }
        // finish() materializes the identical records.
        let result = engine.finish("run-all");
        for (a, b) in result.slots.iter().zip(&records) {
            assert_eq!(a.t, b.t);
            assert_eq!(a.energy_kwh.to_bits(), b.energy_kwh.to_bits());
        }
    }

    /// The pre-SoA engine, retained verbatim as the semantic reference: a
    /// struct-per-job state vector, per-slot allocating view construction,
    /// per-struct feature walks, and [`reference_sanitize`]. The columnar
    /// production engine must reproduce its [`SimResult::fingerprint`]
    /// bitwise on any input.
    fn aos_reference_run(
        cfg: &Simulator,
        jobs: &[Job],
        forecaster: &Forecaster,
        policy: &mut dyn Policy,
    ) -> SimResult {
        struct St {
            remaining: f64,
            prev_alloc: usize,
            started: bool,
            done: bool,
            energy_kwh: f64,
            carbon_g: f64,
            rescales: usize,
        }
        let mut st: Vec<St> = jobs
            .iter()
            .map(|j| St {
                remaining: j.work(),
                prev_alloc: 0,
                started: false,
                done: false,
                energy_kwh: 0.0,
                carbon_g: 0.0,
                rescales: 0,
            })
            .collect();
        let mut outcomes: Vec<JobOutcome> = Vec::new();
        let mut slots: Vec<SlotRecord> = Vec::new();
        let mut usage_per_slot: Vec<usize> = Vec::new();
        // Dependency gating, AoS style: a job is active only once arrived
        // AND every parent was done at the start of the slot; the first
        // slot it qualifies is its `eligible_since`.
        let mut first_eligible: Vec<Option<usize>> = vec![None; jobs.len()];
        let mut prev_capacity = cfg.max_capacity;
        let mut prev_used = 0usize;
        let mut overhead_energy = 0.0f64;
        let mut overhead_carbon = 0.0f64;
        let mut recent: VecDeque<(usize, bool)> = VecDeque::new();
        let mut pending = jobs.len();
        let last_arrival = jobs.iter().map(|j| j.arrival).max().unwrap_or(0);
        let t_end = last_arrival + cfg.horizon + cfg.max_drain_slots;
        let mut t = 0usize;
        while pending > 0 && t < t_end {
            let active: Vec<usize> = (0..jobs.len())
                .filter(|&i| {
                    jobs[i].arrival <= t
                        && !st[i].done
                        && jobs[i].deps.iter().all(|&p| st[p].done)
                })
                .collect();
            for &i in &active {
                if first_eligible[i].is_none() {
                    first_eligible[i] = Some(if jobs[i].deps.is_empty() { jobs[i].arrival } else { t });
                }
            }
            if active.is_empty() {
                prev_used = 0;
                usage_per_slot.push(0);
                slots.push(SlotRecord {
                    t,
                    ci: forecaster.truth().at(t),
                    provisioned: 0,
                    used: 0,
                    rho: 1.0,
                    queue_lengths: [0; MAX_QUEUES],
                    mean_elasticity: 0.0,
                    energy_kwh: 0.0,
                    carbon_g: 0.0,
                });
                t += 1;
                continue;
            }
            while let Some(&(ct, _)) = recent.front() {
                if ct + 24 <= t {
                    recent.pop_front();
                } else {
                    break;
                }
            }
            let recent_violation_rate = if recent.is_empty() {
                0.0
            } else {
                recent.iter().filter(|(_, v)| *v).count() as f64 / recent.len() as f64
            };
            let views: Vec<JobView> = active
                .iter()
                .map(|&i| {
                    let jv = JobView {
                        job: &jobs[i],
                        remaining: st[i].remaining,
                        prev_alloc: st[i].prev_alloc,
                        overdue: false,
                        eligible_since: first_eligible[i].unwrap_or(jobs[i].arrival),
                    };
                    let overdue = jv.slack_left(t) <= 0.0;
                    JobView { overdue, ..jv }
                })
                .collect();
            // AoS Table 2 features: per-struct walks, the pre-columnar code.
            let mut queue_lengths = [0usize; MAX_QUEUES];
            let top = cfg.num_queues.max(1).min(MAX_QUEUES) - 1;
            for jv in &views {
                queue_lengths[jv.job.queue.min(top)] += 1;
            }
            let mean_elasticity =
                views.iter().map(|j| j.job.elasticity()).sum::<f64>() / views.len() as f64;
            let cols = JobViewCols::from_views(&views);
            let ctx = SlotCtx {
                t,
                jobs: &views,
                cols: &cols,
                forecaster,
                max_capacity: cfg.max_capacity,
                num_queues: cfg.num_queues,
                prev_capacity,
                prev_used,
                recent_violation_rate,
            };
            let mut decision = Decision::default();
            policy.decide_into(&ctx, &mut decision);
            let (provisioned, alloc) = reference_sanitize(cfg.max_capacity, &decision, &views);

            let ci = forecaster.truth().at(t);
            let mut slot_energy = 0.0f64;
            let mut slot_carbon = 0.0f64;
            let mut used = 0usize;
            let mut rho: f64 = f64::INFINITY;
            let mut any_ran = false;
            for (idx, &i) in active.iter().enumerate() {
                let k = alloc[idx];
                let s = &mut st[i];
                let job = &jobs[i];
                if k == 0 {
                    if s.prev_alloc > 0 {
                        s.rescales += 1;
                    }
                    s.prev_alloc = 0;
                    continue;
                }
                any_ran = true;
                used += k;
                rho = rho.min(job.marginal(k));
                let rate = job.rate(k);
                let mut penalty = 0.0;
                if s.started && s.prev_alloc != k && s.prev_alloc > 0 {
                    s.rescales += 1;
                    penalty = cfg.energy.ckpt_progress_penalty(rate);
                }
                s.started = true;
                let progress = (rate - penalty).max(0.0);
                let (fraction, finished) = if s.remaining <= progress {
                    ((s.remaining + penalty) / rate, true)
                } else {
                    (1.0, false)
                };
                let e = cfg.energy.job_energy_kwh(job, k, fraction.min(1.0));
                s.energy_kwh += e;
                s.carbon_g += e * ci;
                slot_energy += e;
                slot_carbon += e * ci;
                if finished {
                    s.remaining = 0.0;
                    s.done = true;
                    s.prev_alloc = 0;
                    pending -= 1;
                    let outcome = JobOutcome {
                        id: job.id,
                        arrival: job.arrival,
                        completion: t,
                        length_hours: job.length_hours,
                        slack_hours: job.slack_hours,
                        energy_kwh: s.energy_kwh,
                        carbon_g: s.carbon_g,
                        rescales: s.rescales,
                    };
                    recent.push_back((t, outcome.violated_slo()));
                    policy.on_complete(job.id, t);
                    outcomes.push(outcome);
                } else {
                    s.remaining -= progress;
                    s.prev_alloc = k;
                }
            }
            if provisioned > prev_capacity {
                let boot = cfg.energy.boot_energy_kwh(provisioned - prev_capacity);
                overhead_energy += boot;
                overhead_carbon += boot * ci;
            }
            prev_capacity = provisioned;
            prev_used = used;
            let rho = if any_ran { rho } else { RHO_IDLE };
            usage_per_slot.push(used);
            slots.push(SlotRecord {
                t,
                ci,
                provisioned,
                used,
                rho,
                queue_lengths,
                mean_elasticity,
                energy_kwh: slot_energy,
                carbon_g: slot_carbon,
            });
            t += 1;
        }
        let unfinished = st.iter().filter(|s| !s.done).count();
        let mut metrics = RunMetrics::from_outcomes(
            policy.name(),
            &outcomes,
            unfinished,
            &usage_per_slot,
            cfg.max_capacity,
            cfg.horizon,
        );
        metrics.energy_kwh += overhead_energy;
        metrics.carbon_g += overhead_carbon;
        SimResult {
            metrics,
            outcomes,
            slots,
            overhead_energy_kwh: overhead_energy,
            overhead_carbon_g: overhead_carbon,
        }
    }

    /// Adversarial decision stream: random capacities and allocations
    /// (including out-of-range scales) drawn from a seeded RNG, so paired
    /// instances issue identical decisions when fed identical contexts.
    struct RandomDecider(crate::util::rng::Rng);
    impl Policy for RandomDecider {
        fn name(&self) -> &'static str {
            "random"
        }
        fn decide(&mut self, ctx: &SlotCtx) -> Decision {
            let rng = &mut self.0;
            let capacity = rng.below(ctx.max_capacity + 4);
            let mut alloc = Vec::new();
            for v in ctx.jobs {
                if rng.chance(0.8) {
                    alloc.push((v.job.id, rng.below(v.job.k_max + 2)));
                }
            }
            Decision { capacity, alloc }
        }
    }

    /// Property: the columnar engine reproduces the retained AoS reference
    /// bitwise (full [`SimResult::fingerprint`], covering every slot record
    /// and outcome) across random workloads and four policy shapes —
    /// including NeverRun (overdue force-run path), ScaleAll (trim-loop tie
    /// storms), and a random decider (stale ids, out-of-range scales,
    /// mid-run completions tombstoning the job columns).
    #[test]
    fn property_columnar_step_matches_aos_reference() {
        use crate::util::proptest_lite::{check, Config};
        use crate::util::rng::Rng;
        check(
            "columnar engine == AoS reference",
            Config { cases: 48, seed: 0xA05D },
            |rng| {
                let n = 1 + rng.below(10);
                let mut jobs: Vec<Job> = (0..n)
                    .map(|i| {
                        let k_max = 1 + rng.below(4);
                        let mut j = job(
                            i,
                            rng.below(6),
                            0.5 + rng.range(0.0, 5.0),
                            rng.range(0.0, 8.0),
                            k_max,
                        );
                        j.queue = rng.below(3);
                        j.profile = ScalingProfile::from_comm_ratio(rng.range(0.0, 0.25), k_max);
                        j
                    })
                    .collect();
                // Half the cases carry dependency edges, so the engine's DAG
                // gate is pinned against the AoS reference too.
                if rng.chance(0.5) {
                    for i in 1..n {
                        if rng.chance(0.4) {
                            let p = rng.below(i);
                            jobs[i].deps.push(p);
                        }
                    }
                }
                let capacity = 1 + rng.below(8);
                let policy_choice = rng.below(4);
                let policy_seed = rng.below(1 << 30) as u64;
                (jobs, capacity, policy_choice, policy_seed)
            },
            |(jobs, capacity, policy_choice, policy_seed)| {
                fn mk(choice: usize, seed: u64) -> Box<dyn Policy> {
                    match choice {
                        0 => Box::new(RunAll),
                        1 => Box::new(ScaleAll),
                        2 => Box::new(NeverRun),
                        _ => Box::new(RandomDecider(Rng::new(seed))),
                    }
                }
                let hourly: Vec<f64> =
                    (0..128).map(|h| 100.0 + 37.5 * ((h % 24) as f64)).collect();
                let f = Forecaster::perfect(CarbonTrace::new("vary", hourly));
                let s = sim(*capacity, 24);
                let mut prod_policy = mk(*policy_choice, *policy_seed);
                let mut ref_policy = mk(*policy_choice, *policy_seed);
                let got = s.run(jobs, &f, prod_policy.as_mut());
                let want = aos_reference_run(&s, jobs, &f, ref_policy.as_mut());
                if got.fingerprint() != want.fingerprint() {
                    return Err(format!(
                        "fingerprints diverge: got {} want {}",
                        got.fingerprint(),
                        want.fingerprint()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dag_child_waits_for_parent_completion() {
        // Chain 0 ← 1: both arrive at t=0. The child must stay invisible to
        // the policy until the parent completes, then start the next slot.
        let mut child = job(1, 0, 2.0, 6.0, 4);
        child.deps = vec![0];
        let jobs = vec![job(0, 0, 3.0, 6.0, 4), child];
        let f = flat_forecaster(100, 100.0);
        let r = sim(10, 24).run(&jobs, &f, &mut RunAll);
        assert_eq!(r.metrics.completed, 2);
        let done = |id: usize| r.outcomes.iter().find(|o| o.id == id).unwrap().completion;
        // Parent runs slots 0..=2; the child becomes eligible at slot 3 and
        // runs 3..=4 — never concurrently with the parent.
        assert_eq!(done(0), 2);
        assert_eq!(done(1), 4);
        for s in &r.slots {
            assert!(s.used <= 1, "slot {}: parent and child overlapped", s.t);
        }
        // While gated, the child is absent from the policy's queue view.
        assert_eq!(r.slots[0].queue_lengths[0], 1);
    }

    /// Property: every schedule the engine emits under dependency edges is
    /// topologically feasible — a child never completes at or before any of
    /// its parents — across policy shapes, including the overdue force-run
    /// path (which must not override the gate) and a random decider.
    #[test]
    fn property_dag_schedules_are_topologically_feasible() {
        use crate::util::proptest_lite::{check, Config};
        use crate::util::rng::Rng;
        check(
            "DAG schedules are topologically feasible",
            Config { cases: 64, seed: 0xDA6F },
            |rng| {
                let n = 2 + rng.below(9);
                let mut jobs: Vec<Job> = (0..n)
                    .map(|i| {
                        let k_max = 1 + rng.below(4);
                        let mut j = job(
                            i,
                            rng.below(5),
                            0.5 + rng.range(0.0, 4.0),
                            rng.range(0.0, 6.0),
                            k_max,
                        );
                        j.profile = ScalingProfile::from_comm_ratio(rng.range(0.0, 0.25), k_max);
                        j
                    })
                    .collect();
                for i in 1..n {
                    if rng.chance(0.6) {
                        let p = rng.below(i);
                        jobs[i].deps.push(p);
                    }
                }
                let capacity = 1 + rng.below(6);
                let policy_choice = rng.below(3);
                let policy_seed = rng.below(1 << 30) as u64;
                (jobs, capacity, policy_choice, policy_seed)
            },
            |(jobs, capacity, policy_choice, policy_seed)| {
                let mut policy: Box<dyn Policy> = match policy_choice {
                    0 => Box::new(RunAll),
                    1 => Box::new(NeverRun),
                    _ => Box::new(RandomDecider(Rng::new(*policy_seed))),
                };
                let f = flat_forecaster(512, 120.0);
                let r = sim(*capacity, 24).run(jobs, &f, policy.as_mut());
                if r.metrics.completed != jobs.len() {
                    return Err(format!(
                        "{} of {} jobs completed",
                        r.metrics.completed,
                        jobs.len()
                    ));
                }
                let mut completion = vec![0usize; jobs.len()];
                for o in &r.outcomes {
                    completion[o.id] = o.completion;
                }
                for j in jobs {
                    for &p in &j.deps {
                        if completion[j.id] <= completion[p] {
                            return Err(format!(
                                "child {} completed at {} but parent {p} only at {}",
                                j.id, completion[j.id], completion[p]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
