//! System-state representation (paper Table 2).
//!
//! The state captured per slot, used both when recording oracle decisions
//! (learning phase) and when matching at runtime (execution phase):
//!
//! | feature | Table 2 entry |
//! |---|---|
//! | 0 | CI_t (normalized) |
//! | 1 | CI gradient ∇CI (normalized, signed) |
//! | 2 | CI^R: day-ahead rank of slot t |
//! | 3–5 | queue length per queue (short/medium/long) |
//! | 6 | mean elasticity of active jobs |
//! | 7 | total queued jobs (system pressure) |
//!
//! Raw features are pre-scaled to comparable ranges here; the knowledge
//! base additionally z-score-normalizes them over its cases before the
//! Euclidean k-NN match (the paper uses scikit-learn KNN, where
//! standardization is the stock preprocessing). The vector is fixed at
//! [`STATE_DIM`] = 8 — the same dimension the AOT-compiled Pallas distance
//! kernel is built for.

/// Dimensionality of the state vector (must match `python/compile/model.py`).
pub const STATE_DIM: usize = 8;

/// Squared Euclidean distance over two contiguous coordinate slices.
///
/// §Perf: the structure-of-arrays KD-tree stores point coordinates as one
/// flat `f64` array (stride [`STATE_DIM`]), so the match inner loop calls
/// this on raw slices instead of going through [`StateVector`]. The
/// iteration order and operation sequence are identical to
/// [`StateVector::dist2`] (which delegates here), keeping results bitwise
/// equal to the AoS path.
#[inline]
pub fn dist2_flat(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// Normalization constants.
const CI_SCALE: f64 = 700.0; // g/kWh full scale
const GRAD_SCALE: f64 = 100.0; // g/kWh per hour
const QUEUE_SCALE: f64 = 50.0; // jobs per queue

/// A normalized state vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StateVector(pub [f64; STATE_DIM]);

impl StateVector {
    /// Build from raw system measurements.
    ///
    /// `queue_lengths` is padded/truncated to 3 queues (the paper's
    /// short/medium/long setup).
    pub fn from_raw(
        ci: f64,
        ci_gradient: f64,
        day_ahead_rank: f64,
        queue_lengths: &[usize],
        mean_elasticity: f64,
    ) -> StateVector {
        let mut f = [0.0f64; STATE_DIM];
        f[0] = (ci / CI_SCALE).clamp(0.0, 2.0);
        f[1] = (ci_gradient / GRAD_SCALE).clamp(-2.0, 2.0);
        f[2] = day_ahead_rank.clamp(0.0, 1.0);
        let mut total = 0usize;
        for q in 0..3 {
            let len = queue_lengths.get(q).copied().unwrap_or(0);
            total += len;
            f[3 + q] = (len as f64 / QUEUE_SCALE).min(2.0);
        }
        f[6] = mean_elasticity.clamp(0.0, 1.0);
        f[7] = (total as f64 / (3.0 * QUEUE_SCALE)).min(2.0);
        StateVector(f)
    }

    /// Squared Euclidean distance.
    pub fn dist2(&self, other: &StateVector) -> f64 {
        dist2_flat(&self.0, &other.0)
    }

    /// Euclidean distance.
    pub fn dist(&self, other: &StateVector) -> f64 {
        self.dist2(other).sqrt()
    }

    pub fn as_array(&self) -> &[f64; STATE_DIM] {
        &self.0
    }

    /// Lossless CSV cell encoding (semicolon-separated features).
    pub fn to_csv_cell(&self) -> String {
        self.0.iter().map(|v| format!("{v:.6}")).collect::<Vec<_>>().join(";")
    }

    /// Parse the [`to_csv_cell`] encoding. Single pass, no intermediate
    /// vector — this runs once per line when loading trace-catalog-sized
    /// knowledge bases from CSV.
    pub fn from_csv_cell(s: &str) -> Option<StateVector> {
        let mut f = [0.0; STATE_DIM];
        let mut parts = s.split(';');
        for v in f.iter_mut() {
            *v = parts.next()?.trim().parse().ok()?;
        }
        if parts.next().is_some() {
            return None; // more than STATE_DIM features
        }
        Some(StateVector(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_ranges() {
        let s = StateVector::from_raw(350.0, -50.0, 0.3, &[10, 5, 0], 0.8);
        assert!((s.0[0] - 0.5).abs() < 1e-9);
        assert!((s.0[1] + 0.5).abs() < 1e-9);
        assert_eq!(s.0[2], 0.3);
        assert!((s.0[3] - 0.2).abs() < 1e-9);
        assert!((s.0[4] - 0.1).abs() < 1e-9);
        assert_eq!(s.0[5], 0.0);
        assert_eq!(s.0[6], 0.8);
        // total 15 jobs / 150 = 0.1
        assert!((s.0[7] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn clamping() {
        let s = StateVector::from_raw(1e6, -1e6, 7.0, &[1000, 0, 0], 3.0);
        assert_eq!(s.0[0], 2.0);
        assert_eq!(s.0[1], -2.0);
        assert_eq!(s.0[2], 1.0);
        assert_eq!(s.0[3], 2.0);
        assert_eq!(s.0[6], 1.0);
        assert_eq!(s.0[7], 2.0);
    }

    #[test]
    fn distance_metric() {
        let a = StateVector::from_raw(100.0, 0.0, 0.5, &[1, 1, 1], 0.5);
        let b = a;
        assert_eq!(a.dist(&b), 0.0);
        let c = StateVector::from_raw(800.0, 0.0, 0.5, &[1, 1, 1], 0.5);
        assert!(a.dist(&c) > 0.5);
        // Symmetry + triangle sanity.
        assert!((a.dist(&c) - c.dist(&a)).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let s = StateVector::from_raw(421.5, 13.0, 0.7, &[3, 9, 2], 0.66);
        let cell = s.to_csv_cell();
        let back = StateVector::from_csv_cell(&cell).unwrap();
        for i in 0..STATE_DIM {
            assert!((s.0[i] - back.0[i]).abs() < 1e-5);
        }
        assert!(StateVector::from_csv_cell("1;2;3").is_none());
        assert!(StateVector::from_csv_cell("a;b;c;d;e;f;g;h").is_none());
    }

    #[test]
    fn flat_distance_matches_struct_distance_bitwise() {
        let a = StateVector::from_raw(421.5, 13.0, 0.7, &[3, 9, 2], 0.66);
        let b = StateVector::from_raw(118.0, -42.0, 0.1, &[0, 4, 7], 0.31);
        assert_eq!(dist2_flat(&a.0, &b.0).to_bits(), a.dist2(&b).to_bits());
        assert_eq!(dist2_flat(&a.0, &a.0), 0.0);
    }

    #[test]
    fn short_queue_vector_padded() {
        let s = StateVector::from_raw(100.0, 0.0, 0.5, &[4], 0.5);
        assert!(s.0[4] == 0.0 && s.0[5] == 0.0);
    }
}
