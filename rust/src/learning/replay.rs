//! The learning phase (paper §4.2): replay historical windows through the
//! offline oracle and record its `(STATE → m_t, ρ)` decisions.
//!
//! The oracle is simulated — not just planned — so recorded decisions include
//! the effects the prototype would see (forced SLO runs, checkpoint costs).
//! As in the paper's deployment (§6.1), the historical trace is replayed
//! with several start-time offsets to densify the knowledge base.

use crate::carbon::forecast::Forecaster;
use crate::carbon::trace::CarbonTrace;
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::Simulator;
use crate::learning::kb::{Case, KnowledgeBase};
use crate::learning::state::StateVector;
use crate::sched::oracle::Oracle;
use crate::workload::job::Job;

/// Learning-phase configuration.
#[derive(Debug, Clone)]
pub struct LearnConfig {
    pub max_capacity: usize,
    pub num_queues: usize,
    /// Number of start-time offsets to replay (≥ 1); each shifts the carbon
    /// trace by 24 h, exposing the oracle to different job/carbon alignments.
    pub offsets: usize,
    pub energy: EnergyModel,
}

/// Run the learning phase over one historical window.
pub fn learn(jobs: &[Job], trace: &CarbonTrace, cfg: &LearnConfig) -> KnowledgeBase {
    let mut kb = KnowledgeBase::new();
    for o in 0..cfg.offsets.max(1) {
        let shift = o * 24;
        if shift + 48 >= trace.len() {
            break; // not enough trace left for a meaningful replay
        }
        let shifted = trace.slice(shift, trace.len() - shift);
        record_replay(jobs, &shifted, cfg, &mut kb);
    }
    kb.rebuild();
    kb
}

/// Replay one oracle run and append its per-slot cases.
fn record_replay(jobs: &[Job], trace: &CarbonTrace, cfg: &LearnConfig, kb: &mut KnowledgeBase) {
    let horizon = jobs.iter().map(|j| j.arrival).max().unwrap_or(0) + 24;
    let forecaster = Forecaster::perfect(trace.clone());
    let mut oracle = Oracle::new(jobs, trace, cfg.max_capacity);
    let sim = Simulator::new(cfg.max_capacity, cfg.energy.clone(), cfg.num_queues, horizon);
    let result = sim.run(jobs, &forecaster, &mut oracle);

    for rec in &result.slots {
        let state = StateVector::from_raw(
            rec.ci,
            trace.gradient(rec.t),
            trace.day_ahead_rank(rec.t),
            &rec.queue_lengths,
            rec.mean_elasticity,
        );
        kb.push(Case { recorded_at: rec.t, state, capacity: rec.used, rho: rec.rho });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::synth::{synthesize, Region};
    use crate::config::{ExperimentConfig, Hardware};
    use crate::learning::kb::Matcher;
    use crate::workload::tracegen;

    fn learn_config() -> LearnConfig {
        LearnConfig {
            max_capacity: 20,
            num_queues: 3,
            offsets: 2,
            energy: EnergyModel::for_hardware(Hardware::Cpu),
        }
    }

    #[test]
    fn learning_builds_nonempty_kb() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 96, 1);
        let trace = synthesize(Region::SouthAustralia, 400, 2);
        let kb = learn(&jobs, &trace, &learn_config());
        assert!(kb.len() > 100, "kb has {} cases", kb.len());
        // Matching works end-to-end.
        let q = StateVector::from_raw(200.0, 0.0, 0.3, &[2, 1, 0], 0.6);
        let hits = kb.top_k(&q, 5);
        assert_eq!(hits.len(), 5);
    }

    #[test]
    fn offsets_densify_kb() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 96, 3);
        let trace = synthesize(Region::California, 600, 4);
        let mut one = learn_config();
        one.offsets = 1;
        let mut three = learn_config();
        three.offsets = 3;
        let kb1 = learn(&jobs, &trace, &one);
        let kb3 = learn(&jobs, &trace, &three);
        assert!(kb3.len() > kb1.len() * 2, "{} vs {}", kb3.len(), kb1.len());
    }

    #[test]
    fn low_ci_states_learn_higher_capacity() {
        // In a variable region, the oracle should on average use more
        // servers in clean slots than in dirty ones.
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 168, 5);
        let trace = synthesize(Region::SouthAustralia, 600, 6);
        let kb = learn(&jobs, &trace, &learn_config());
        let mean_ci = trace.mean();
        let (mut clean_cap, mut clean_n, mut dirty_cap, mut dirty_n) = (0.0, 0, 0.0, 0);
        for c in kb.cases() {
            // Only consider states with work available.
            if c.state.0[3] + c.state.0[4] + c.state.0[5] <= 0.0 {
                continue;
            }
            let ci = c.state.0[0] * 700.0;
            if ci < mean_ci * 0.7 {
                clean_cap += c.capacity as f64;
                clean_n += 1;
            } else if ci > mean_ci * 1.3 {
                dirty_cap += c.capacity as f64;
                dirty_n += 1;
            }
        }
        assert!(clean_n > 0 && dirty_n > 0);
        let clean_avg = clean_cap / clean_n as f64;
        let dirty_avg = dirty_cap / dirty_n as f64;
        assert!(
            clean_avg > dirty_avg,
            "oracle should provision more in clean slots: clean {clean_avg:.1} dirty {dirty_avg:.1}"
        );
    }
}
