//! The learning phase (paper §4.2): replay historical windows through the
//! offline oracle and record its `(STATE → m_t, ρ)` decisions.
//!
//! The oracle is simulated — not just planned — so recorded decisions include
//! the effects the prototype would see (forced SLO runs, checkpoint costs).
//! As in the paper's deployment (§6.1), the historical trace is replayed
//! with several start-time offsets to densify the knowledge base.
//!
//! §Perf: the per-offset replays are independent oracle simulations, so
//! [`learn`] fans them out on the sweep engine's
//! [`par_map`](crate::experiments::sweep::par_map) thread pool and merges
//! the recorded cases **in offset order** — the learned knowledge base is
//! bitwise identical for any thread count (the continuous-learning loops
//! in `experiments/yearlong.rs` re-learn every window, so this sits on
//! their critical path).

use crate::carbon::forecast::Forecaster;
use crate::carbon::trace::CarbonTrace;
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::Simulator;
use crate::experiments::sweep::{auto_threads, par_map};
use crate::learning::kb::{Case, KnowledgeBase};
use crate::learning::state::StateVector;
use crate::sched::oracle::Oracle;
use crate::workload::job::Job;

/// Learning-phase configuration.
#[derive(Debug, Clone)]
pub struct LearnConfig {
    pub max_capacity: usize,
    pub num_queues: usize,
    /// Number of start-time offsets to replay (≥ 1); each shifts the carbon
    /// trace by 24 h, exposing the oracle to different job/carbon alignments.
    pub offsets: usize,
    pub energy: EnergyModel,
    /// Worker threads for the per-offset replays (0 = one per core). The
    /// result is identical for any value; this only trades wall time.
    pub threads: usize,
}

/// Run the learning phase over one historical window.
pub fn learn(jobs: &[Job], trace: &CarbonTrace, cfg: &LearnConfig) -> KnowledgeBase {
    // Offsets that leave enough trace behind for a meaningful replay.
    let shifts: Vec<usize> = (0..cfg.offsets.max(1))
        .map(|o| o * 24)
        .take_while(|&shift| shift + 48 < trace.len())
        .collect();
    let threads = if cfg.threads == 0 { auto_threads() } else { cfg.threads };
    let recorded: Vec<Vec<Case>> = par_map(threads, &shifts, |&shift, _| {
        let shifted = trace.slice(shift, trace.len() - shift);
        record_replay(jobs, &shifted, cfg)
    });
    let mut cases = Vec::with_capacity(recorded.iter().map(Vec::len).sum());
    for r in recorded {
        cases.extend(r);
    }
    KnowledgeBase::from_cases(cases)
}

/// Replay one oracle run and return its per-slot cases.
fn record_replay(jobs: &[Job], trace: &CarbonTrace, cfg: &LearnConfig) -> Vec<Case> {
    let horizon = jobs.iter().map(|j| j.arrival).max().unwrap_or(0) + 24;
    let forecaster = Forecaster::perfect(trace.clone());
    let mut oracle = Oracle::new(jobs, trace, cfg.max_capacity);
    let sim = Simulator::new(cfg.max_capacity, cfg.energy.clone(), cfg.num_queues, horizon);
    let result = sim.run(jobs, &forecaster, &mut oracle);

    let mut cases = Vec::with_capacity(result.slots.len());
    for rec in &result.slots {
        let state = StateVector::from_raw(
            rec.ci,
            trace.gradient(rec.t),
            trace.day_ahead_rank(rec.t),
            &rec.queue_lengths,
            rec.mean_elasticity,
        );
        cases.push(Case { recorded_at: rec.t, state, capacity: rec.used, rho: rec.rho });
    }
    cases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::synth::{synthesize, Region};
    use crate::config::{ExperimentConfig, Hardware};
    use crate::learning::kb::Matcher;
    use crate::workload::tracegen;

    fn learn_config() -> LearnConfig {
        LearnConfig {
            max_capacity: 20,
            num_queues: 3,
            offsets: 2,
            energy: EnergyModel::for_hardware(Hardware::Cpu),
            threads: 0,
        }
    }

    #[test]
    fn learning_builds_nonempty_kb() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 96, 1);
        let trace = synthesize(Region::SouthAustralia, 400, 2);
        let kb = learn(&jobs, &trace, &learn_config());
        assert!(kb.len() > 100, "kb has {} cases", kb.len());
        // Matching works end-to-end.
        let q = StateVector::from_raw(200.0, 0.0, 0.3, &[2, 1, 0], 0.6);
        let hits = kb.top_k(&q, 5);
        assert_eq!(hits.len(), 5);
    }

    #[test]
    fn offsets_densify_kb() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 96, 3);
        let trace = synthesize(Region::California, 600, 4);
        let mut one = learn_config();
        one.offsets = 1;
        let mut three = learn_config();
        three.offsets = 3;
        let kb1 = learn(&jobs, &trace, &one);
        let kb3 = learn(&jobs, &trace, &three);
        assert!(kb3.len() > kb1.len() * 2, "{} vs {}", kb3.len(), kb1.len());
    }

    #[test]
    fn parallel_learning_is_thread_count_invariant() {
        // Any worker count must produce the same knowledge base, case for
        // case, in the same (offset-major) order.
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 16;
        let jobs = tracegen::generate(&cfg, 96, 11);
        let trace = synthesize(Region::Ontario, 500, 12);
        let mut serial = learn_config();
        serial.offsets = 4;
        serial.threads = 1;
        let mut parallel = serial.clone();
        parallel.threads = 4;
        let kb1 = learn(&jobs, &trace, &serial);
        let kb4 = learn(&jobs, &trace, &parallel);
        assert_eq!(kb1.len(), kb4.len());
        for (a, b) in kb1.cases().iter().zip(kb4.cases()) {
            assert_eq!(a, b);
        }
        // And the fitted scalers (hence every future match) agree bitwise.
        assert_eq!(kb1.scaler(), kb4.scaler());
    }

    #[test]
    fn low_ci_states_learn_higher_capacity() {
        // In a variable region, the oracle should on average use more
        // servers in clean slots than in dirty ones.
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 168, 5);
        let trace = synthesize(Region::SouthAustralia, 600, 6);
        let kb = learn(&jobs, &trace, &learn_config());
        let mean_ci = trace.mean();
        let (mut clean_cap, mut clean_n, mut dirty_cap, mut dirty_n) = (0.0, 0, 0.0, 0);
        for c in kb.cases() {
            // Only consider states with work available.
            if c.state.0[3] + c.state.0[4] + c.state.0[5] <= 0.0 {
                continue;
            }
            let ci = c.state.0[0] * 700.0;
            if ci < mean_ci * 0.7 {
                clean_cap += c.capacity as f64;
                clean_n += 1;
            } else if ci > mean_ci * 1.3 {
                dirty_cap += c.capacity as f64;
                dirty_n += 1;
            }
        }
        assert!(clean_n > 0 && dirty_n > 0);
        let clean_avg = clean_cap / clean_n as f64;
        let dirty_avg = dirty_cap / dirty_n as f64;
        assert!(
            clean_avg > dirty_avg,
            "oracle should provision more in clean slots: clean {clean_avg:.1} dirty {dirty_avg:.1}"
        );
    }
}
