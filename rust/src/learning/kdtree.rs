//! KD-tree for k-nearest-neighbour state matching.
//!
//! The paper's prototype uses scikit-learn's KD-tree (§5, "represent the
//! historical cases in a KD-Tree for fast access"); this is the equivalent
//! Rust substrate. Points are [`STATE_DIM`]-dimensional; payloads are case
//! indices into the knowledge base.
//!
//! §Perf: the tree is a **flat, contiguous node array** instead of a
//! `Box`-per-node pointer graph. Nodes are laid out in pre-order (a node's
//! near/left subtree starts at `slot + 1`), so the descent that dominates
//! every query walks the arrays forward instead of chasing heap pointers.
//! Per-slot data is stored as parallel slices (point coordinates in slot
//! order, original case index, splitting axis, child slots), built in
//! O(n log n) via `select_nth_unstable_by` median selection with an explicit
//! index tie-break (the previous build was an O(n log² n) stable full sort
//! per level), so the build is input-order deterministic.
//!
//! Results are deterministic and traversal-order independent: hits are
//! ordered by `(distance, case index)`, so exact-distance ties always
//! resolve to the lower case index (the in-test brute-force and recursive
//! references pin this bit for bit).

use crate::learning::state::{dist2_flat, StateVector, STATE_DIM};

/// Child-slot sentinel ("no subtree").
const NONE: u32 = u32::MAX;

/// Immutable KD-tree built over a set of state vectors, stored as a flat
/// node array (see the module docs for the layout). `Clone` is a plain
/// memcpy of the arrays — snapshotting a built index costs O(n), not the
/// O(n log n) rebuild a boxed-node tree would force.
#[derive(Debug, Clone)]
pub struct KdTree {
    /// Point coordinates in slot (pre-order) order, flattened into one
    /// contiguous `f64` array with stride [`STATE_DIM`] (slot `s` occupies
    /// `s*STATE_DIM .. (s+1)*STATE_DIM`): the descent reads this array
    /// mostly front-to-back, and the distance inner loop runs over raw
    /// slices ([`dist2_flat`]) instead of per-point structs.
    points: Vec<f64>,
    /// slot → original point index (the case index reported in hits).
    case: Vec<u32>,
    /// slot → splitting axis (depth % [`STATE_DIM`]).
    axis: Vec<u8>,
    /// slot → left child slot ([`NONE`] when the left subtree is empty).
    /// Always `slot + 1` in the pre-order layout; kept explicit so the
    /// traversal needs no subtree-size bookkeeping.
    left: Vec<u32>,
    /// slot → right child slot ([`NONE`] when the right subtree is empty).
    right: Vec<u32>,
}

/// One k-NN result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Index of the matched point (case index).
    pub index: usize,
    /// Euclidean distance.
    pub dist: f64,
}

impl KdTree {
    /// Build from points in O(n log n) median splits.
    pub fn build(points: Vec<StateVector>) -> KdTree {
        let n = points.len();
        assert!(n < NONE as usize, "kd-tree capped at u32 point indices");
        let mut tree = KdTree {
            points: Vec::with_capacity(n * STATE_DIM),
            case: Vec::with_capacity(n),
            axis: Vec::with_capacity(n),
            left: Vec::with_capacity(n),
            right: Vec::with_capacity(n),
        };
        let mut idx: Vec<u32> = (0..n as u32).collect();
        if n > 0 {
            tree.build_slot(&points, &mut idx, 0);
        }
        tree
    }

    /// Lay out `idx`'s subtree starting at the next free slot; returns the
    /// subtree root's slot. `select_nth_unstable_by` partitions around the
    /// median in O(len) per level (O(n log n) total). The comparator breaks
    /// axis-value ties by original index, so the build is deterministic;
    /// the *hit sets* are tree-shape independent anyway, because the search
    /// ranks by the total order `(distance, case index)`.
    fn build_slot(&mut self, points: &[StateVector], idx: &mut [u32], depth: usize) -> u32 {
        let axis = depth % STATE_DIM;
        let mid = idx.len() / 2;
        if idx.len() > 1 {
            idx.select_nth_unstable_by(mid, |&a, &b| {
                points[a as usize].0[axis]
                    .partial_cmp(&points[b as usize].0[axis])
                    .expect("state coordinates are never NaN")
                    .then(a.cmp(&b))
            });
        }
        let point = idx[mid];
        let slot = self.case.len() as u32;
        self.points.extend_from_slice(&points[point as usize].0);
        self.case.push(point);
        self.axis.push(axis as u8);
        self.left.push(NONE);
        self.right.push(NONE);
        let (left, rest) = idx.split_at_mut(mid);
        let right = &mut rest[1..];
        if !left.is_empty() {
            let child = self.build_slot(points, left, depth + 1);
            self.left[slot as usize] = child;
        }
        if !right.is_empty() {
            let child = self.build_slot(points, right, depth + 1);
            self.right[slot as usize] = child;
        }
        slot
    }

    pub fn len(&self) -> usize {
        self.case.len()
    }

    pub fn is_empty(&self) -> bool {
        self.case.is_empty()
    }

    /// k nearest neighbours of `query`, sorted ascending by
    /// `(distance, case index)`.
    pub fn knn(&self, query: &StateVector, k: usize) -> Vec<Hit> {
        let mut best = Vec::new();
        self.knn_into(query, k, &mut best);
        best
    }

    /// Buffer-reusing k-NN: results replace the contents of `out` (sorted
    /// ascending by `(distance, case index)`). Explicit-stack traversal over
    /// slot indices — no recursion, no heap allocation beyond `out` itself.
    pub fn knn_into(&self, query: &StateVector, k: usize, out: &mut Vec<Hit>) {
        self.knn_filtered_into(query, k, |_| true, out);
    }

    /// [`knn_into`](KdTree::knn_into) restricted to points whose case index
    /// satisfies `keep` — the knowledge base's lazy aging skips tombstoned
    /// cases this way without rebuilding the tree. Pruning geometry is
    /// unaffected by the filter (only result admission is), so the hits are
    /// exactly the top-k over the kept subset.
    pub fn knn_filtered_into<F: Fn(usize) -> bool>(
        &self,
        query: &StateVector,
        k: usize,
        keep: F,
        out: &mut Vec<Hit>,
    ) {
        out.clear();
        if k == 0 || self.case.is_empty() {
            return;
        }
        out.reserve(k + 1);
        self.search(query, k, &keep, out, 0);
    }

    /// Batched multi-query k-NN: hits for query `i` land in
    /// `out[offsets[i]..offsets[i + 1]]`, each group sorted ascending by
    /// `(distance, case index)` — identical to `queries.len()` independent
    /// [`knn_into`](KdTree::knn_into) calls, but with one output reservation
    /// and one scratch set amortized across the whole batch.
    pub fn knn_batch_into(
        &self,
        queries: &[StateVector],
        k: usize,
        out: &mut Vec<Hit>,
        offsets: &mut Vec<usize>,
    ) {
        out.clear();
        offsets.clear();
        offsets.reserve(queries.len() + 1);
        offsets.push(0);
        if k == 0 || self.case.is_empty() {
            offsets.resize(queries.len() + 1, 0);
            return;
        }
        // +1: a segment transiently holds k+1 hits before the worst pops.
        out.reserve(queries.len().saturating_mul(k.min(self.case.len())) + 1);
        for q in queries {
            let start = out.len();
            self.search(q, k, &|_| true, out, start);
            offsets.push(out.len());
        }
    }

    /// Core search: append the top-k hits for `query` into `out[start..]`,
    /// sorted ascending by `(distance, case index)`. Distances are taken to
    /// Euclidean (sqrt) space **at insertion**, so the ranking space is
    /// exactly the one callers see and merge against (the knowledge base's
    /// brute-force tail, the in-test references) — ordering by d² and
    /// sqrt-ing afterwards could disagree with a post-sqrt merge when two
    /// distinct d² values round to the same square root. The far subtree is
    /// revisited when its splitting-plane distance is at most the current
    /// worst (`<=`, not `<`): a far point at exactly the worst distance but
    /// with a smaller case index must still displace the worst hit for the
    /// `(distance, index)` order to be exact.
    fn search<F: Fn(usize) -> bool>(
        &self,
        query: &StateVector,
        k: usize,
        keep: &F,
        out: &mut Vec<Hit>,
        start: usize,
    ) {
        // Deferred far subtrees: (slot, |split-plane distance|). The median
        // build halves subtree sizes per level, so depth ≤ log2(n) + 1 and
        // a fixed 64-slot stack covers any in-memory tree.
        const MAX_DEPTH: usize = 64;
        let mut stack = [(NONE, 0.0f64); MAX_DEPTH];
        let mut sp = 0usize;
        let mut cur = 0u32; // root slot (the array is non-empty here)
        loop {
            // Descend the near side, deferring each far child.
            while cur != NONE {
                let s = cur as usize;
                let case = self.case[s] as usize;
                let coords = &self.points[s * STATE_DIM..(s + 1) * STATE_DIM];
                if keep(case) {
                    let d = dist2_flat(coords, &query.0).sqrt();
                    let pos = out[start..]
                        .partition_point(|h| h.dist < d || (h.dist == d && h.index < case));
                    if pos < k {
                        out.insert(start + pos, Hit { index: case, dist: d });
                        if out.len() - start > k {
                            out.pop();
                        }
                    }
                }
                let axis = self.axis[s] as usize;
                let diff = query.0[axis] - coords[axis];
                let (near, far) = if diff <= 0.0 {
                    (self.left[s], self.right[s])
                } else {
                    (self.right[s], self.left[s])
                };
                if far != NONE {
                    debug_assert!(sp < MAX_DEPTH, "kd-tree deeper than {MAX_DEPTH}");
                    stack[sp] = (far, diff.abs());
                    sp += 1;
                }
                cur = near;
            }
            // Pop the most recent deferred far subtree; prune it unless the
            // splitting plane could still admit a hit under the
            // (distance, index) order. `plane` (= |diff|) lower-bounds every
            // far point's true distance, and IEEE sqrt is monotone, so
            // `plane > worst` proves no far point can enter the results.
            cur = NONE;
            while sp > 0 {
                sp -= 1;
                let (slot, plane) = stack[sp];
                let worst = if out.len() > start {
                    out[out.len() - 1].dist
                } else {
                    f64::INFINITY
                };
                if out.len() - start < k || plane <= worst {
                    cur = slot;
                    break;
                }
            }
            if cur == NONE {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, Config};
    use crate::util::rng::Rng;

    fn random_state(rng: &mut Rng) -> StateVector {
        let mut f = [0.0; STATE_DIM];
        for v in f.iter_mut() {
            *v = rng.range(-1.0, 1.0);
        }
        StateVector(f)
    }

    /// States drawn from a coarse grid, so exact coordinate and distance
    /// ties occur constantly (the property tests lean on this).
    fn grid_state(rng: &mut Rng) -> StateVector {
        let mut f = [0.0; STATE_DIM];
        for v in f.iter_mut() {
            *v = rng.below(3) as f64 * 0.5 - 0.5; // {-0.5, 0, 0.5}
        }
        StateVector(f)
    }

    /// Brute-force k-NN with the (distance, case index) order — the ground
    /// truth the tree must reproduce bitwise, including exact ties.
    fn brute(points: &[StateVector], q: &StateVector, k: usize) -> Vec<Hit> {
        let mut hits: Vec<Hit> = points
            .iter()
            .enumerate()
            .map(|(i, p)| Hit { index: i, dist: p.dist(q) })
            .collect();
        hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.index.cmp(&b.index)));
        hits.truncate(k);
        hits
    }

    fn assert_bitwise_eq(got: &[Hit], want: &[Hit], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: lengths differ");
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.index, w.index, "{ctx}: got {got:?} want {want:?}");
            assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "{ctx}: got {got:?} want {want:?}");
        }
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(42);
        let points: Vec<StateVector> = (0..500).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        for i in 0..50 {
            let q = random_state(&mut rng);
            assert_bitwise_eq(&tree.knn(&q, 5), &brute(&points, &q, 5), &format!("query {i}"));
        }
    }

    #[test]
    fn exact_match_found() {
        let mut rng = Rng::new(7);
        let points: Vec<StateVector> = (0..100).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        let hits = tree.knn(&points[37], 1);
        assert_eq!(hits[0].index, 37);
        assert!(hits[0].dist < 1e-12);
    }

    #[test]
    fn k_larger_than_n() {
        let mut rng = Rng::new(9);
        let points: Vec<StateVector> = (0..3).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        assert_eq!(tree.knn(&random_state(&mut rng), 10).len(), 3);
    }

    #[test]
    fn empty_tree() {
        let tree = KdTree::build(vec![]);
        assert!(tree.knn(&StateVector([0.0; STATE_DIM]), 5).is_empty());
        assert!(tree.is_empty());
        let mut out = Vec::new();
        let mut offsets = Vec::new();
        tree.knn_batch_into(&[StateVector([0.0; STATE_DIM])], 5, &mut out, &mut offsets);
        assert!(out.is_empty());
        assert_eq!(offsets, vec![0, 0]);
    }

    /// The pre-flat-tree boxed-node build (stable axis sort) and recursive
    /// search, kept as the reference: the flat-array build + explicit-stack
    /// iteration must reproduce it bitwise. The search carries the same
    /// (distance, case index) tie order as the production path.
    struct RefNode {
        point: usize,
        axis: usize,
        left: Option<Box<RefNode>>,
        right: Option<Box<RefNode>>,
    }

    fn ref_build(points: &[StateVector], idx: &mut [usize], depth: usize) -> Option<Box<RefNode>> {
        if idx.is_empty() {
            return None;
        }
        let axis = depth % STATE_DIM;
        // Stable sort on the axis value: ties keep index order, the same
        // total order as the production build's explicit tie-break.
        idx.sort_by(|&a, &b| points[a].0[axis].partial_cmp(&points[b].0[axis]).unwrap());
        let mid = idx.len() / 2;
        let point = idx[mid];
        let (left, rest) = idx.split_at_mut(mid);
        let right = &mut rest[1..];
        Some(Box::new(RefNode {
            point,
            axis,
            left: ref_build(points, left, depth + 1),
            right: ref_build(points, right, depth + 1),
        }))
    }

    fn ref_search(
        points: &[StateVector],
        node: Option<&RefNode>,
        query: &StateVector,
        k: usize,
        best: &mut Vec<Hit>,
    ) {
        let Some(n) = node else { return };
        let d = points[n.point].dist2(query).sqrt();
        let pos = best.partition_point(|h| h.dist < d || (h.dist == d && h.index < n.point));
        if pos < k {
            best.insert(pos, Hit { index: n.point, dist: d });
            if best.len() > k {
                best.pop();
            }
        }
        let diff = query.0[n.axis] - points[n.point].0[n.axis];
        let (near, far) = if diff <= 0.0 {
            (n.left.as_deref(), n.right.as_deref())
        } else {
            (n.right.as_deref(), n.left.as_deref())
        };
        ref_search(points, near, query, k, best);
        let worst = best.last().map(|h| h.dist).unwrap_or(f64::INFINITY);
        if best.len() < k || diff.abs() <= worst {
            ref_search(points, far, query, k, best);
        }
    }

    fn recursive_knn(points: &[StateVector], query: &StateVector, k: usize) -> Vec<Hit> {
        if k == 0 || points.is_empty() {
            return vec![];
        }
        let mut idx: Vec<usize> = (0..points.len()).collect();
        let root = ref_build(points, &mut idx, 0);
        let mut best: Vec<Hit> = Vec::with_capacity(k + 1);
        ref_search(points, root.as_deref(), query, k, &mut best);
        best
    }

    #[test]
    fn flat_tree_matches_recursive_reference() {
        let mut rng = Rng::new(0x5EED);
        for n in [1usize, 2, 3, 17, 200, 1000] {
            let points: Vec<StateVector> = (0..n).map(|_| random_state(&mut rng)).collect();
            let tree = KdTree::build(points.clone());
            for _ in 0..25 {
                let q = random_state(&mut rng);
                for k in [1usize, 5, 16] {
                    let got = tree.knn(&q, k);
                    let want = recursive_knn(&points, &q, k);
                    assert_bitwise_eq(&got, &want, &format!("n={n} k={k}"));
                }
            }
        }
    }

    #[test]
    fn duplicate_points_tie_break_by_index() {
        // Every point identical: the k nearest are exactly indices 0..k.
        let p = StateVector([0.25; STATE_DIM]);
        let tree = KdTree::build(vec![p; 9]);
        let hits = tree.knn(&p, 4);
        assert_eq!(hits.iter().map(|h| h.index).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(hits.iter().all(|h| h.dist == 0.0));
    }

    #[test]
    fn filtered_search_skips_tombstones_exactly() {
        let mut rng = Rng::new(0xF1);
        let points: Vec<StateVector> = (0..300).map(|_| grid_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        let mut out = Vec::new();
        for trial in 0..20usize {
            let q = grid_state(&mut rng);
            // Drop every third point (offset by trial) from consideration.
            let keep = |i: usize| i % 3 != trial % 3;
            tree.knn_filtered_into(&q, 7, keep, &mut out);
            let kept: Vec<StateVector> =
                points.iter().enumerate().filter(|(i, _)| keep(*i)).map(|(_, p)| *p).collect();
            let mut want = brute(&kept, &q, 7);
            // Map compacted brute indices back to original indices.
            let orig: Vec<usize> = (0..points.len()).filter(|&i| keep(i)).collect();
            for h in want.iter_mut() {
                h.index = orig[h.index];
            }
            assert_bitwise_eq(&out, &want, &format!("trial {trial}"));
        }
    }

    #[test]
    fn knn_into_reuses_buffer_and_clears_stale_results() {
        let mut rng = Rng::new(21);
        let points: Vec<StateVector> = (0..300).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        let mut buf = Vec::new();
        let mut last_cap = 0usize;
        for i in 0..50 {
            let q = random_state(&mut rng);
            tree.knn_into(&q, 5, &mut buf);
            assert_eq!(buf.len(), 5);
            assert_eq!(buf, tree.knn(&q, 5), "iteration {i}");
            if i > 0 {
                assert_eq!(buf.capacity(), last_cap, "buffer reallocated at iteration {i}");
            }
            last_cap = buf.capacity();
        }
        // k = 0 and empty trees clear the buffer instead of keeping stale hits.
        tree.knn_into(&random_state(&mut rng), 0, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn results_sorted_ascending() {
        let mut rng = Rng::new(11);
        let points: Vec<StateVector> = (0..200).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        let hits = tree.knn(&random_state(&mut rng), 8);
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    /// Property: the flat (structure-of-arrays) tree's *filtered* search —
    /// the lazy-aging tombstone path — matches the AoS brute force
    /// ([`StateVector::dist`] over struct points) bitwise, across random
    /// grid-valued point sets (dense exact-distance ties) and random
    /// tombstone masks including all-dead and all-alive.
    #[test]
    fn property_filtered_flat_matches_aos_brute() {
        check(
            "flat filtered knn == AoS brute",
            Config { cases: 96, seed: 0x50A7 },
            |rng| {
                let n = rng.below(40);
                let points: Vec<StateVector> = (0..n).map(|_| grid_state(rng)).collect();
                // 0 = all dead, 1 = all alive, otherwise i.i.d. coin flips.
                let dead: Vec<bool> = match rng.below(4) {
                    0 => vec![true; n],
                    1 => vec![false; n],
                    _ => (0..n).map(|_| rng.below(2) == 0).collect(),
                };
                let q = grid_state(rng);
                let k = rng.below(n + 3);
                (points, dead, q, k)
            },
            |(points, dead, q, k)| {
                let tree = KdTree::build(points.clone());
                let mut out = Vec::new();
                tree.knn_filtered_into(q, *k, |i| !dead[i], &mut out);
                let mut want: Vec<Hit> = points
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !dead[*i])
                    .map(|(i, p)| Hit { index: i, dist: p.dist(q) })
                    .collect();
                want.sort_by(|a, b| {
                    a.dist.partial_cmp(&b.dist).unwrap().then(a.index.cmp(&b.index))
                });
                want.truncate(*k);
                if out.len() != want.len() {
                    return Err(format!("lens: got {} want {}", out.len(), want.len()));
                }
                for (j, (g, w)) in out.iter().zip(&want).enumerate() {
                    if g.index != w.index || g.dist.to_bits() != w.dist.to_bits() {
                        return Err(format!("hit {j}: got {g:?} want {w:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: batched kNN == per-query kNN == brute force, across random
    /// grid-valued point sets (dense exact-distance ties), k = 0, k > n, and
    /// empty point sets included.
    #[test]
    fn property_batch_equals_single_equals_brute() {
        check(
            "knn_batch == knn == brute",
            Config { cases: 96, seed: 0xD15C },
            |rng| {
                let n = rng.below(48);
                let points: Vec<StateVector> = (0..n).map(|_| grid_state(rng)).collect();
                let queries: Vec<StateVector> =
                    (0..1 + rng.below(4)).map(|_| grid_state(rng)).collect();
                let k = rng.below(n + 4); // covers 0, 1..n, and k > n
                (points, queries, k)
            },
            |(points, queries, k)| {
                let tree = KdTree::build(points.clone());
                let mut out = Vec::new();
                let mut offsets = Vec::new();
                tree.knn_batch_into(queries, *k, &mut out, &mut offsets);
                if offsets.len() != queries.len() + 1 {
                    return Err(format!("offsets len {} != {}", offsets.len(), queries.len() + 1));
                }
                for (qi, q) in queries.iter().enumerate() {
                    let seg = &out[offsets[qi]..offsets[qi + 1]];
                    let single = tree.knn(q, *k);
                    let want = brute(points, q, *k);
                    if seg.len() != single.len() || single.len() != want.len() {
                        return Err(format!(
                            "query {qi}: lens batch={} single={} brute={}",
                            seg.len(),
                            single.len(),
                            want.len()
                        ));
                    }
                    for j in 0..want.len() {
                        for (label, got) in [("batch", &seg[j]), ("single", &single[j])] {
                            if got.index != want[j].index
                                || got.dist.to_bits() != want[j].dist.to_bits()
                            {
                                return Err(format!(
                                    "query {qi} hit {j} ({label}): got {got:?} want {:?}",
                                    want[j]
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
