//! KD-tree for k-nearest-neighbour state matching.
//!
//! The paper's prototype uses scikit-learn's KD-tree (§5, "represent the
//! historical cases in a KD-Tree for fast access"); this is the equivalent
//! Rust substrate. Points are [`STATE_DIM`]-dimensional; payloads are case
//! indices into the knowledge base.

use crate::learning::state::{StateVector, STATE_DIM};

#[derive(Debug)]
struct Node {
    /// Index into `points`.
    point: usize,
    axis: usize,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

/// Immutable KD-tree built over a set of state vectors.
#[derive(Debug)]
pub struct KdTree {
    points: Vec<StateVector>,
    root: Option<Box<Node>>,
}

/// One k-NN result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Index of the matched point (case index).
    pub index: usize,
    /// Euclidean distance.
    pub dist: f64,
}

impl KdTree {
    /// Build from points (O(n log² n) median splits).
    pub fn build(points: Vec<StateVector>) -> KdTree {
        let mut idx: Vec<usize> = (0..points.len()).collect();
        let root = Self::build_node(&points, &mut idx, 0);
        KdTree { points, root }
    }

    fn build_node(points: &[StateVector], idx: &mut [usize], depth: usize) -> Option<Box<Node>> {
        if idx.is_empty() {
            return None;
        }
        let axis = depth % STATE_DIM;
        idx.sort_by(|&a, &b| points[a].0[axis].partial_cmp(&points[b].0[axis]).unwrap());
        let mid = idx.len() / 2;
        let point = idx[mid];
        let (left, rest) = idx.split_at_mut(mid);
        let right = &mut rest[1..];
        Some(Box::new(Node {
            point,
            axis,
            left: Self::build_node(points, left, depth + 1),
            right: Self::build_node(points, right, depth + 1),
        }))
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// k nearest neighbours of `query`, sorted by ascending distance.
    pub fn knn(&self, query: &StateVector, k: usize) -> Vec<Hit> {
        let mut best = Vec::new();
        self.knn_into(query, k, &mut best);
        best
    }

    /// Buffer-reusing k-NN: results replace the contents of `out` (sorted
    /// ascending by distance). §Perf: the traversal is an explicit-stack
    /// iteration — no per-node call overhead, no heap allocation beyond
    /// `out` itself — and visits nodes in exactly the recursive order, so
    /// results (including distance ties) are bitwise identical to the
    /// historical recursive search (`iterative_search_matches_recursive`).
    pub fn knn_into(&self, query: &StateVector, k: usize, out: &mut Vec<Hit>) {
        out.clear();
        if k == 0 || self.points.is_empty() {
            return;
        }
        out.reserve(k + 1);
        // Deferred far subtrees: (node, split-plane distance²). The median
        // build halves subtree sizes per level, so depth ≤ log2(n) + 1 and
        // a fixed 64-slot stack covers any in-memory tree.
        const MAX_DEPTH: usize = 64;
        let mut stack: [Option<(&Node, f64)>; MAX_DEPTH] = [None; MAX_DEPTH];
        let mut sp = 0usize;
        let mut cur = self.root.as_deref();
        loop {
            // Descend the near side, recording each node and deferring its
            // far child (recursion's pre-order visit + post-near far check).
            while let Some(n) = cur {
                let d2 = self.points[n.point].dist2(query);
                // Insert into the sorted result list (dist holds d² here).
                let pos = out.partition_point(|h| h.dist <= d2);
                if pos < k {
                    out.insert(pos, Hit { index: n.point, dist: d2 });
                    if out.len() > k {
                        out.pop();
                    }
                }
                let diff = query.0[n.axis] - self.points[n.point].0[n.axis];
                let (near, far) = if diff <= 0.0 {
                    (n.left.as_deref(), n.right.as_deref())
                } else {
                    (n.right.as_deref(), n.left.as_deref())
                };
                if let Some(f) = far {
                    debug_assert!(sp < MAX_DEPTH, "kd-tree deeper than {MAX_DEPTH}");
                    stack[sp] = Some((f, diff * diff));
                    sp += 1;
                }
                cur = near;
            }
            // Pop the most recent deferred far subtree; prune unless the
            // splitting plane is closer than the current k-th best. The
            // check runs exactly when the recursion would have run it —
            // after the sibling near subtree finished.
            cur = None;
            while sp > 0 {
                sp -= 1;
                let (node, plane_d2) = stack[sp].take().expect("pushed entry");
                let worst = out.last().map(|h| h.dist).unwrap_or(f64::INFINITY);
                if out.len() < k || plane_d2 < worst {
                    cur = Some(node);
                    break;
                }
            }
            if cur.is_none() {
                break;
            }
        }
        for h in out.iter_mut() {
            h.dist = h.dist.sqrt();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_state(rng: &mut Rng) -> StateVector {
        let mut f = [0.0; STATE_DIM];
        for v in f.iter_mut() {
            *v = rng.range(-1.0, 1.0);
        }
        StateVector(f)
    }

    /// Brute-force k-NN for cross-checking.
    fn brute(points: &[StateVector], q: &StateVector, k: usize) -> Vec<Hit> {
        let mut hits: Vec<Hit> = points
            .iter()
            .enumerate()
            .map(|(i, p)| Hit { index: i, dist: p.dist(q) })
            .collect();
        hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        hits.truncate(k);
        hits
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(42);
        let points: Vec<StateVector> = (0..500).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        for _ in 0..50 {
            let q = random_state(&mut rng);
            let got = tree.knn(&q, 5);
            let want = brute(&points, &q, 5);
            assert_eq!(got.len(), 5);
            for (g, w) in got.iter().zip(&want) {
                // Distances must agree (indices may tie-swap).
                assert!((g.dist - w.dist).abs() < 1e-9, "got {g:?} want {w:?}");
            }
        }
    }

    #[test]
    fn exact_match_found() {
        let mut rng = Rng::new(7);
        let points: Vec<StateVector> = (0..100).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        let hits = tree.knn(&points[37], 1);
        assert_eq!(hits[0].index, 37);
        assert!(hits[0].dist < 1e-12);
    }

    #[test]
    fn k_larger_than_n() {
        let mut rng = Rng::new(9);
        let points: Vec<StateVector> = (0..3).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        assert_eq!(tree.knn(&random_state(&mut rng), 10).len(), 3);
    }

    #[test]
    fn empty_tree() {
        let tree = KdTree::build(vec![]);
        assert!(tree.knn(&StateVector([0.0; STATE_DIM]), 5).is_empty());
        assert!(tree.is_empty());
    }

    /// The pre-optimization recursive search, kept as the traversal-order
    /// reference: the explicit-stack iteration must match it bitwise,
    /// including tie resolution.
    fn recursive_search(
        tree: &KdTree,
        node: Option<&Node>,
        query: &StateVector,
        k: usize,
        best: &mut Vec<Hit>,
    ) {
        let Some(n) = node else { return };
        let d2 = tree.points[n.point].dist2(query);
        let pos = best.partition_point(|h| h.dist <= d2);
        if pos < k {
            best.insert(pos, Hit { index: n.point, dist: d2 });
            if best.len() > k {
                best.pop();
            }
        }
        let diff = query.0[n.axis] - tree.points[n.point].0[n.axis];
        let (near, far) = if diff <= 0.0 {
            (n.left.as_deref(), n.right.as_deref())
        } else {
            (n.right.as_deref(), n.left.as_deref())
        };
        recursive_search(tree, near, query, k, best);
        let worst = best.last().map(|h| h.dist).unwrap_or(f64::INFINITY);
        if best.len() < k || diff * diff < worst {
            recursive_search(tree, far, query, k, best);
        }
    }

    fn recursive_knn(tree: &KdTree, query: &StateVector, k: usize) -> Vec<Hit> {
        if k == 0 || tree.points.is_empty() {
            return vec![];
        }
        let mut best: Vec<Hit> = Vec::with_capacity(k + 1);
        recursive_search(tree, tree.root.as_deref(), query, k, &mut best);
        for h in best.iter_mut() {
            h.dist = h.dist.sqrt();
        }
        best
    }

    #[test]
    fn iterative_search_matches_recursive() {
        let mut rng = Rng::new(0x5EED);
        for n in [1usize, 2, 3, 17, 200, 1000] {
            let points: Vec<StateVector> = (0..n).map(|_| random_state(&mut rng)).collect();
            let tree = KdTree::build(points);
            for _ in 0..25 {
                let q = random_state(&mut rng);
                for k in [1usize, 5, 16] {
                    let got = tree.knn(&q, k);
                    let want = recursive_knn(&tree, &q, k);
                    assert_eq!(got.len(), want.len(), "n={n} k={k}");
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(g.index, w.index, "n={n} k={k}");
                        assert_eq!(g.dist.to_bits(), w.dist.to_bits(), "n={n} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn knn_into_reuses_buffer_and_clears_stale_results() {
        let mut rng = Rng::new(21);
        let points: Vec<StateVector> = (0..300).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        let mut buf = Vec::new();
        let mut last_cap = 0usize;
        for i in 0..50 {
            let q = random_state(&mut rng);
            tree.knn_into(&q, 5, &mut buf);
            assert_eq!(buf.len(), 5);
            assert_eq!(buf, tree.knn(&q, 5), "iteration {i}");
            if i > 0 {
                assert_eq!(buf.capacity(), last_cap, "buffer reallocated at iteration {i}");
            }
            last_cap = buf.capacity();
        }
        // k = 0 and empty trees clear the buffer instead of keeping stale hits.
        tree.knn_into(&random_state(&mut rng), 0, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn results_sorted_ascending() {
        let mut rng = Rng::new(11);
        let points: Vec<StateVector> = (0..200).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        let hits = tree.knn(&random_state(&mut rng), 8);
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }
}
