//! KD-tree for k-nearest-neighbour state matching.
//!
//! The paper's prototype uses scikit-learn's KD-tree (§5, "represent the
//! historical cases in a KD-Tree for fast access"); this is the equivalent
//! Rust substrate. Points are [`STATE_DIM`]-dimensional; payloads are case
//! indices into the knowledge base.

use crate::learning::state::{StateVector, STATE_DIM};

#[derive(Debug)]
struct Node {
    /// Index into `points`.
    point: usize,
    axis: usize,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

/// Immutable KD-tree built over a set of state vectors.
#[derive(Debug)]
pub struct KdTree {
    points: Vec<StateVector>,
    root: Option<Box<Node>>,
}

/// One k-NN result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Index of the matched point (case index).
    pub index: usize,
    /// Euclidean distance.
    pub dist: f64,
}

impl KdTree {
    /// Build from points (O(n log² n) median splits).
    pub fn build(points: Vec<StateVector>) -> KdTree {
        let mut idx: Vec<usize> = (0..points.len()).collect();
        let root = Self::build_node(&points, &mut idx, 0);
        KdTree { points, root }
    }

    fn build_node(points: &[StateVector], idx: &mut [usize], depth: usize) -> Option<Box<Node>> {
        if idx.is_empty() {
            return None;
        }
        let axis = depth % STATE_DIM;
        idx.sort_by(|&a, &b| points[a].0[axis].partial_cmp(&points[b].0[axis]).unwrap());
        let mid = idx.len() / 2;
        let point = idx[mid];
        let (left, rest) = idx.split_at_mut(mid);
        let right = &mut rest[1..];
        Some(Box::new(Node {
            point,
            axis,
            left: Self::build_node(points, left, depth + 1),
            right: Self::build_node(points, right, depth + 1),
        }))
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// k nearest neighbours of `query`, sorted by ascending distance.
    pub fn knn(&self, query: &StateVector, k: usize) -> Vec<Hit> {
        if k == 0 || self.points.is_empty() {
            return vec![];
        }
        // Small bounded max-heap as a sorted vec (k ≤ ~16 in practice).
        let mut best: Vec<Hit> = Vec::with_capacity(k + 1);
        self.search(self.root.as_deref(), query, k, &mut best);
        for h in best.iter_mut() {
            h.dist = h.dist.sqrt();
        }
        best
    }

    fn search(&self, node: Option<&Node>, query: &StateVector, k: usize, best: &mut Vec<Hit>) {
        let Some(n) = node else { return };
        let d2 = self.points[n.point].dist2(query);
        // Insert into the sorted result list (dist field holds d² here).
        let pos = best.partition_point(|h| h.dist <= d2);
        if pos < k {
            best.insert(pos, Hit { index: n.point, dist: d2 });
            if best.len() > k {
                best.pop();
            }
        }
        let diff = query.0[n.axis] - self.points[n.point].0[n.axis];
        let (near, far) = if diff <= 0.0 {
            (n.left.as_deref(), n.right.as_deref())
        } else {
            (n.right.as_deref(), n.left.as_deref())
        };
        self.search(near, query, k, best);
        // Prune the far side unless the splitting plane is closer than the
        // current k-th best.
        let worst = best.last().map(|h| h.dist).unwrap_or(f64::INFINITY);
        if best.len() < k || diff * diff < worst {
            self.search(far, query, k, best);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_state(rng: &mut Rng) -> StateVector {
        let mut f = [0.0; STATE_DIM];
        for v in f.iter_mut() {
            *v = rng.range(-1.0, 1.0);
        }
        StateVector(f)
    }

    /// Brute-force k-NN for cross-checking.
    fn brute(points: &[StateVector], q: &StateVector, k: usize) -> Vec<Hit> {
        let mut hits: Vec<Hit> = points
            .iter()
            .enumerate()
            .map(|(i, p)| Hit { index: i, dist: p.dist(q) })
            .collect();
        hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        hits.truncate(k);
        hits
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(42);
        let points: Vec<StateVector> = (0..500).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        for _ in 0..50 {
            let q = random_state(&mut rng);
            let got = tree.knn(&q, 5);
            let want = brute(&points, &q, 5);
            assert_eq!(got.len(), 5);
            for (g, w) in got.iter().zip(&want) {
                // Distances must agree (indices may tie-swap).
                assert!((g.dist - w.dist).abs() < 1e-9, "got {g:?} want {w:?}");
            }
        }
    }

    #[test]
    fn exact_match_found() {
        let mut rng = Rng::new(7);
        let points: Vec<StateVector> = (0..100).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points.clone());
        let hits = tree.knn(&points[37], 1);
        assert_eq!(hits[0].index, 37);
        assert!(hits[0].dist < 1e-12);
    }

    #[test]
    fn k_larger_than_n() {
        let mut rng = Rng::new(9);
        let points: Vec<StateVector> = (0..3).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        assert_eq!(tree.knn(&random_state(&mut rng), 10).len(), 3);
    }

    #[test]
    fn empty_tree() {
        let tree = KdTree::build(vec![]);
        assert!(tree.knn(&StateVector([0.0; STATE_DIM]), 5).is_empty());
        assert!(tree.is_empty());
    }

    #[test]
    fn results_sorted_ascending() {
        let mut rng = Rng::new(11);
        let points: Vec<StateVector> = (0..200).map(|_| random_state(&mut rng)).collect();
        let tree = KdTree::build(points);
        let hits = tree.knn(&random_state(&mut rng), 8);
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }
}
