//! Continuous historical learning (paper §4.2): state features (Table 2),
//! the knowledge base of oracle decisions, KD-tree k-NN matching, and the
//! oracle-replay learning phase.

pub mod kb;
pub mod kdtree;
pub mod replay;
pub mod state;

pub use kb::{Case, KnowledgeBase, Matcher, Neighbor};
pub use replay::{learn, LearnConfig};
pub use state::{StateVector, STATE_DIM};
