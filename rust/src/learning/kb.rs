//! The knowledge base: `(STATE → m_t, ρ)` cases recorded from simulated
//! oracle runs (paper §4.2), with rolling-window aging and CSV persistence.
//!
//! Matching is case-based reasoning (§5): the runtime queries the top-k
//! closest historical states (Euclidean, KD-tree) and mimics their
//! decisions. Two interchangeable matcher backends exist: this module's
//! native KD-tree and the PJRT-executed Pallas distance kernel
//! (`runtime::matcher`) — tests assert they agree.

use std::io::Write;
use std::path::Path;

use crate::learning::kdtree::KdTree;
use crate::learning::state::{StateVector, STATE_DIM};

/// One recorded oracle decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Case {
    /// Slot timestamp (hours since the epoch of the learning trace) — used
    /// only for aging.
    pub recorded_at: usize,
    pub state: StateVector,
    /// Cluster capacity the oracle used in this state.
    pub capacity: usize,
    /// Scheduling threshold ρ implied by the oracle's allocation.
    pub rho: f64,
}

/// A k-NN match result carrying the neighbour's decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub dist: f64,
    pub capacity: usize,
    pub rho: f64,
    /// The case's queue-pressure feature (state\[7\]) — used for CBR case
    /// adaptation: the retrieved capacity is rescaled by the ratio of the
    /// query's pressure to the case's.
    pub pressure: f64,
}

/// Matcher abstraction so the CarbonFlex policy can run against either the
/// native KD-tree or the AOT/PJRT kernel. (Deliberately not `Send`-bound:
/// PJRT client handles are thread-local; `CarbonFlex<KnowledgeBase>` remains
/// `Send` for the coordinator, `CarbonFlex<PjrtMatcher>` is single-thread.)
pub trait Matcher {
    /// Top-k nearest recorded cases, ascending by distance.
    fn top_k(&self, query: &StateVector, k: usize) -> Vec<Neighbor>;
    /// Buffer-reusing variant for per-slot matching (§Perf): results
    /// replace the contents of `out`. Takes `&mut self` so backends can
    /// reuse internal scratch; the default delegates to [`top_k`](Matcher::top_k).
    fn top_k_into(&mut self, query: &StateVector, k: usize, out: &mut Vec<Neighbor>) {
        out.clear();
        out.extend(self.top_k(query, k));
    }
    /// Number of cases available.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-feature z-score scaler fitted on the knowledge base's cases, so the
/// Euclidean match weighs every feature by its actual variability (the
/// stock preprocessing for scikit-learn KNN, which the paper's prototype
/// uses). Shared with the PJRT matcher so both backends agree bit-for-bit
/// on the normalized space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scaler {
    pub mean: [f64; STATE_DIM],
    pub std: [f64; STATE_DIM],
}

impl Scaler {
    /// Fit over a set of cases. Near-constant features get σ = 1 so they
    /// contribute their raw (tiny) differences instead of exploding.
    pub fn fit(cases: &[Case]) -> Scaler {
        let n = cases.len().max(1) as f64;
        let mut mean = [0.0f64; STATE_DIM];
        let mut std = [0.0f64; STATE_DIM];
        for c in cases {
            for (i, v) in c.state.0.iter().enumerate() {
                mean[i] += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for c in cases {
            for (i, v) in c.state.0.iter().enumerate() {
                std[i] += (v - mean[i]) * (v - mean[i]);
            }
        }
        for s in std.iter_mut() {
            *s = (*s / n).sqrt();
            if *s < 1e-3 {
                *s = 1.0;
            }
        }
        Scaler { mean, std }
    }

    /// Identity scaler (used before any cases exist).
    pub fn identity() -> Scaler {
        Scaler { mean: [0.0; STATE_DIM], std: [1.0; STATE_DIM] }
    }

    /// Normalize a state into z-space.
    pub fn apply(&self, s: &StateVector) -> StateVector {
        let mut out = [0.0f64; STATE_DIM];
        for i in 0..STATE_DIM {
            out[i] = (s.0[i] - self.mean[i]) / self.std[i];
        }
        StateVector(out)
    }
}

/// The knowledge base.
pub struct KnowledgeBase {
    cases: Vec<Case>,
    scaler: Scaler,
    tree: Option<KdTree>,
    /// Reusable KD-tree hit buffer for [`Matcher::top_k_into`].
    hits: Vec<crate::learning::kdtree::Hit>,
}

impl std::fmt::Debug for KnowledgeBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KnowledgeBase({} cases)", self.cases.len())
    }
}

impl KnowledgeBase {
    pub fn new() -> Self {
        KnowledgeBase { cases: vec![], scaler: Scaler::identity(), tree: None, hits: vec![] }
    }

    pub fn from_cases(cases: Vec<Case>) -> Self {
        let mut kb = KnowledgeBase { cases, scaler: Scaler::identity(), tree: None, hits: vec![] };
        kb.rebuild();
        kb
    }

    /// The scaler fitted at the last [`rebuild`].
    pub fn scaler(&self) -> Scaler {
        self.scaler
    }

    pub fn cases(&self) -> &[Case] {
        &self.cases
    }

    /// Add a case (invalidates the index; call [`rebuild`] before matching).
    pub fn push(&mut self, case: Case) {
        self.cases.push(case);
        self.tree = None;
    }

    /// Drop cases older than `window` relative to `now` (the paper ages out
    /// old mappings over a rolling window to track seasonal drift).
    pub fn age_out(&mut self, now: usize, window: usize) {
        let before = self.cases.len();
        self.cases.retain(|c| c.recorded_at + window >= now);
        if self.cases.len() != before {
            self.tree = None;
        }
    }

    /// (Re)build the KD-tree index (and refit the feature scaler).
    pub fn rebuild(&mut self) {
        self.scaler = Scaler::fit(&self.cases);
        let scaler = self.scaler;
        self.tree =
            Some(KdTree::build(self.cases.iter().map(|c| scaler.apply(&c.state)).collect()));
    }

    /// Persist as CSV: `recorded_at,state(;-separated),capacity,rho`.
    pub fn save_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "recorded_at,state,capacity,rho")?;
        for c in &self.cases {
            writeln!(f, "{},{},{},{:.6}", c.recorded_at, c.state.to_csv_cell(), c.capacity, c.rho)?;
        }
        Ok(())
    }

    /// Load the [`save_csv`] format.
    pub fn load_csv(path: impl AsRef<Path>) -> std::io::Result<KnowledgeBase> {
        let src = std::fs::read_to_string(path)?;
        let mut cases = Vec::new();
        for (i, line) in src.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split(',').collect();
            let bad =
                || std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {}", i + 1));
            if parts.len() != 4 {
                return Err(bad());
            }
            cases.push(Case {
                recorded_at: parts[0].trim().parse().map_err(|_| bad())?,
                state: StateVector::from_csv_cell(parts[1]).ok_or_else(bad)?,
                capacity: parts[2].trim().parse().map_err(|_| bad())?,
                rho: parts[3].trim().parse().map_err(|_| bad())?,
            });
        }
        Ok(KnowledgeBase::from_cases(cases))
    }
}

impl Default for KnowledgeBase {
    fn default() -> Self {
        Self::new()
    }
}

impl Matcher for KnowledgeBase {
    fn top_k(&self, query: &StateVector, k: usize) -> Vec<Neighbor> {
        let q = self.scaler.apply(query);
        let Some(tree) = &self.tree else {
            // Unindexed fallback: brute force in z-space (small KBs, tests;
            // note the identity scaler applies until the first rebuild).
            let mut hits: Vec<Neighbor> = self
                .cases
                .iter()
                .map(|c| Neighbor {
                    dist: self.scaler.apply(&c.state).dist(&q),
                    capacity: c.capacity,
                    rho: c.rho,
                    pressure: c.state.0[7],
                })
                .collect();
            hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
            hits.truncate(k);
            return hits;
        };
        tree.knn(&q, k)
            .into_iter()
            .map(|h| Neighbor {
                dist: h.dist,
                capacity: self.cases[h.index].capacity,
                rho: self.cases[h.index].rho,
                pressure: self.cases[h.index].state.0[7],
            })
            .collect()
    }

    fn top_k_into(&mut self, query: &StateVector, k: usize, out: &mut Vec<Neighbor>) {
        let Some(tree) = &self.tree else {
            // Unindexed fallback (small KBs, tests): delegate to the
            // allocating brute-force path.
            out.clear();
            out.extend(self.top_k(query, k));
            return;
        };
        // §Perf: the hot path of the CarbonFlex decide loop — one KD-tree
        // query into the reusable hit buffer, mapped straight into `out`.
        let q = self.scaler.apply(query);
        tree.knn_into(&q, k, &mut self.hits);
        out.clear();
        out.reserve(self.hits.len());
        for h in &self.hits {
            out.push(Neighbor {
                dist: h.dist,
                capacity: self.cases[h.index].capacity,
                rho: self.cases[h.index].rho,
                pressure: self.cases[h.index].state.0[7],
            });
        }
    }

    fn len(&self) -> usize {
        self.cases.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(at: usize, ci: f64, cap: usize, rho: f64) -> Case {
        Case {
            recorded_at: at,
            state: StateVector::from_raw(ci, 0.0, 0.5, &[2, 1, 0], 0.6),
            capacity: cap,
            rho,
        }
    }

    #[test]
    fn match_returns_nearest_decision() {
        let mut kb = KnowledgeBase::new();
        kb.push(case(0, 100.0, 50, 0.8));
        kb.push(case(1, 600.0, 10, 1.01));
        kb.rebuild();
        let q = StateVector::from_raw(120.0, 0.0, 0.5, &[2, 1, 0], 0.6);
        let hits = kb.top_k(&q, 1);
        assert_eq!(hits[0].capacity, 50);
        assert!((hits[0].rho - 0.8).abs() < 1e-9);
    }

    #[test]
    fn indexed_matches_brute_force_in_z_space() {
        let mut kb = KnowledgeBase::new();
        for i in 0..50 {
            kb.push(case(i, 50.0 * i as f64 % 700.0, i, 0.5 + (i % 5) as f64 / 10.0));
        }
        kb.rebuild();
        let q = StateVector::from_raw(333.0, 0.0, 0.5, &[2, 1, 0], 0.6);
        let indexed = kb.top_k(&q, 5);
        // Brute force with the fitted scaler.
        let scaler = kb.scaler();
        let zq = scaler.apply(&q);
        let mut brute: Vec<f64> =
            kb.cases().iter().map(|c| scaler.apply(&c.state).dist(&zq)).collect();
        brute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (a, b) in indexed.iter().zip(&brute) {
            assert!((a.dist - b).abs() < 1e-9, "{} vs {}", a.dist, b);
        }
    }

    #[test]
    fn top_k_into_matches_top_k() {
        let mut kb = KnowledgeBase::new();
        for i in 0..60 {
            kb.push(case(i, (37 * i) as f64 % 700.0, i, 0.4 + (i % 7) as f64 / 10.0));
        }
        // Unindexed fallback path first, then the KD-tree path.
        let q = StateVector::from_raw(250.0, 10.0, 0.4, &[3, 1, 0], 0.5);
        let mut buf = Vec::new();
        for rebuilt in [false, true] {
            if rebuilt {
                kb.rebuild();
            }
            let direct = kb.top_k(&q, 5);
            kb.top_k_into(&q, 5, &mut buf);
            assert_eq!(buf.len(), direct.len(), "rebuilt={rebuilt}");
            for (a, b) in buf.iter().zip(&direct) {
                assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "rebuilt={rebuilt}");
                assert_eq!(a.capacity, b.capacity);
                assert_eq!(a.rho.to_bits(), b.rho.to_bits());
            }
        }
    }

    #[test]
    fn aging_drops_old_cases() {
        let mut kb = KnowledgeBase::new();
        for i in 0..10 {
            kb.push(case(i * 100, 200.0, i, 1.0));
        }
        kb.age_out(1000, 350);
        assert_eq!(kb.len(), 3); // recorded_at ≥ 650 → 700, 800, 900
        assert!(kb.cases().iter().all(|c| c.recorded_at + 350 >= 1000));
    }

    #[test]
    fn csv_roundtrip() {
        let mut kb = KnowledgeBase::new();
        for i in 0..20 {
            kb.push(case(i, 37.0 * i as f64, 150 - i, 0.25 + i as f64 / 100.0));
        }
        let dir = std::env::temp_dir().join("carbonflex_kb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.csv");
        kb.save_csv(&path).unwrap();
        let loaded = KnowledgeBase::load_csv(&path).unwrap();
        assert_eq!(loaded.len(), 20);
        for (a, b) in kb.cases().iter().zip(loaded.cases()) {
            assert_eq!(a.recorded_at, b.recorded_at);
            assert_eq!(a.capacity, b.capacity);
            assert!((a.rho - b.rho).abs() < 1e-5);
        }
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("carbonflex_kb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "recorded_at,state,capacity,rho\n1,notastate,5,0.5\n").unwrap();
        assert!(KnowledgeBase::load_csv(&path).is_err());
    }
}
