//! The knowledge base: `(STATE → m_t, ρ)` cases recorded from simulated
//! oracle runs (paper §4.2), with rolling-window aging and CSV persistence.
//!
//! Matching is case-based reasoning (§5): the runtime queries the top-k
//! closest historical states (Euclidean, KD-tree) and mimics their
//! decisions. Two interchangeable matcher backends exist: this module's
//! native KD-tree and the PJRT-executed Pallas distance kernel
//! (`runtime::matcher`) — tests assert they agree.
//!
//! §Perf: sliding-window maintenance is **amortized**. Cases pushed after
//! the last [`rebuild`](KnowledgeBase::rebuild) are matched brute-force in
//! the same z-space and merged with the tree hits; cases that fall out of
//! the rolling window are tombstoned (skipped at match time via the tree's
//! filtered search) instead of being removed. A full reclaim + rebuild runs
//! only when accumulated churn — tombstones plus unindexed tail — exceeds a
//! configurable fraction of the indexed set (`CARBONFLEX_KB_CHURN`, default
//! 0.25), so continuous-learning loops (yearlong, week-window sweeps) stop
//! paying an O(n log n) rebuild every window slide. Hit sets are always
//! exact over the live cases; ties resolve by ascending case index.

use std::io::Write;
use std::path::Path;

use crate::learning::kdtree::{Hit, KdTree};
use crate::learning::state::{StateVector, STATE_DIM};

/// One recorded oracle decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Case {
    /// Slot timestamp (hours since the epoch of the learning trace) — used
    /// only for aging.
    pub recorded_at: usize,
    pub state: StateVector,
    /// Cluster capacity the oracle used in this state.
    pub capacity: usize,
    /// Scheduling threshold ρ implied by the oracle's allocation.
    pub rho: f64,
}

/// A k-NN match result carrying the neighbour's decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub dist: f64,
    pub capacity: usize,
    pub rho: f64,
    /// The case's queue-pressure feature (state\[7\]) — used for CBR case
    /// adaptation: the retrieved capacity is rescaled by the ratio of the
    /// query's pressure to the case's.
    pub pressure: f64,
}

/// Matcher abstraction so the CarbonFlex policy can run against either the
/// native KD-tree or the AOT/PJRT kernel. (Deliberately not `Send`-bound:
/// PJRT client handles are thread-local; `CarbonFlex<KnowledgeBase>` remains
/// `Send` for the coordinator, `CarbonFlex<PjrtMatcher>` is single-thread.)
pub trait Matcher {
    /// Top-k nearest recorded cases, ascending by distance.
    fn top_k(&self, query: &StateVector, k: usize) -> Vec<Neighbor>;
    /// Buffer-reusing variant for per-slot matching (§Perf): results
    /// replace the contents of `out`. Takes `&mut self` so backends can
    /// reuse internal scratch; the default delegates to [`top_k`](Matcher::top_k).
    fn top_k_into(&mut self, query: &StateVector, k: usize, out: &mut Vec<Neighbor>) {
        out.clear();
        out.extend(self.top_k(query, k));
    }
    /// Batched multi-query variant: neighbours for query `i` land in
    /// `out[offsets[i]..offsets[i + 1]]`, one scratch set amortized across
    /// the whole batch. The default loops [`top_k_into`](Matcher::top_k_into)
    /// through a reused staging buffer; backends with batch-native paths
    /// (the KD-tree) override it.
    fn top_k_batch_into(
        &mut self,
        queries: &[StateVector],
        k: usize,
        out: &mut Vec<Neighbor>,
        offsets: &mut Vec<usize>,
    ) {
        out.clear();
        offsets.clear();
        offsets.reserve(queries.len() + 1);
        offsets.push(0);
        let mut staging = Vec::new();
        for q in queries {
            self.top_k_into(q, k, &mut staging);
            out.extend_from_slice(&staging);
            offsets.push(out.len());
        }
    }
    /// Number of cases available.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-feature z-score scaler fitted on the knowledge base's cases, so the
/// Euclidean match weighs every feature by its actual variability (the
/// stock preprocessing for scikit-learn KNN, which the paper's prototype
/// uses). Shared with the PJRT matcher so both backends agree bit-for-bit
/// on the normalized space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scaler {
    pub mean: [f64; STATE_DIM],
    pub std: [f64; STATE_DIM],
}

impl Scaler {
    /// Fit over a set of cases. Near-constant features get σ = 1 so they
    /// contribute their raw (tiny) differences instead of exploding.
    pub fn fit(cases: &[Case]) -> Scaler {
        let n = cases.len().max(1) as f64;
        let mut mean = [0.0f64; STATE_DIM];
        let mut std = [0.0f64; STATE_DIM];
        for c in cases {
            for (i, v) in c.state.0.iter().enumerate() {
                mean[i] += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for c in cases {
            for (i, v) in c.state.0.iter().enumerate() {
                std[i] += (v - mean[i]) * (v - mean[i]);
            }
        }
        for s in std.iter_mut() {
            *s = (*s / n).sqrt();
            if *s < 1e-3 {
                *s = 1.0;
            }
        }
        Scaler { mean, std }
    }

    /// Identity scaler (used before any cases exist).
    pub fn identity() -> Scaler {
        Scaler { mean: [0.0; STATE_DIM], std: [1.0; STATE_DIM] }
    }

    /// Normalize a state into z-space.
    pub fn apply(&self, s: &StateVector) -> StateVector {
        let mut out = [0.0f64; STATE_DIM];
        for i in 0..STATE_DIM {
            out[i] = (s.0[i] - self.mean[i]) / self.std[i];
        }
        StateVector(out)
    }
}

/// Default churn fraction before a lazy window slide triggers a full
/// reclaim + rebuild (see [`KnowledgeBase::advance_window`]).
pub const DEFAULT_CHURN_FRACTION: f64 = 0.25;

/// Resolve the lazy-rebuild churn threshold from `CARBONFLEX_KB_CHURN`
/// (read once at knowledge-base construction, never on the match path).
/// Unset, malformed, or negative values fall back to
/// [`DEFAULT_CHURN_FRACTION`]; `0` rebuilds on every slide (the historical
/// eager behaviour).
pub fn churn_fraction_from_env() -> f64 {
    std::env::var("CARBONFLEX_KB_CHURN")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|f| f.is_finite() && *f >= 0.0)
        .unwrap_or(DEFAULT_CHURN_FRACTION)
}

/// The knowledge base. `Clone` duplicates the flat index by memcpy (no
/// rebuild), so per-run snapshots in continuous-learning loops stay cheap.
#[derive(Clone)]
pub struct KnowledgeBase {
    cases: Vec<Case>,
    scaler: Scaler,
    tree: Option<KdTree>,
    /// `cases[..indexed]` are covered by `tree` (in the scaler's z-space);
    /// the tail `cases[indexed..]` is matched brute-force and merged.
    indexed: usize,
    /// Cases with `recorded_at` below this are tombstoned (dead): skipped
    /// at match time, physically reclaimed at the next rebuild.
    age_floor: usize,
    /// Tombstone count as of the last [`advance_window`](KnowledgeBase::advance_window).
    dead: usize,
    /// Lazy-rebuild threshold: rebuild once (dead + unindexed) exceeds this
    /// fraction of the indexed set.
    churn_fraction: f64,
    /// Reusable KD-tree hit buffer for [`Matcher::top_k_into`].
    hits: Vec<Hit>,
}

impl std::fmt::Debug for KnowledgeBase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KnowledgeBase({} cases, {} live)", self.cases.len(), self.live())
    }
}

impl KnowledgeBase {
    pub fn new() -> Self {
        KnowledgeBase {
            cases: vec![],
            scaler: Scaler::identity(),
            tree: None,
            indexed: 0,
            age_floor: 0,
            dead: 0,
            churn_fraction: churn_fraction_from_env(),
            hits: vec![],
        }
    }

    pub fn from_cases(cases: Vec<Case>) -> Self {
        let mut kb = KnowledgeBase { cases, ..KnowledgeBase::new() };
        kb.rebuild();
        kb
    }

    /// The scaler fitted at the last [`rebuild`](KnowledgeBase::rebuild).
    pub fn scaler(&self) -> Scaler {
        self.scaler
    }

    pub fn cases(&self) -> &[Case] {
        &self.cases
    }

    /// Cases not yet tombstoned by the rolling window.
    pub fn live(&self) -> usize {
        self.cases.len() - self.dead
    }

    /// Cases pushed since the last rebuild (matched brute-force until then).
    pub fn pending(&self) -> usize {
        self.cases.len() - self.indexed
    }

    /// Override the lazy-rebuild churn threshold (tests, tuning); the
    /// constructor default comes from [`churn_fraction_from_env`].
    pub fn set_churn_fraction(&mut self, fraction: f64) {
        self.churn_fraction = fraction.max(0.0);
    }

    /// Add a case. The index stays valid: until the next rebuild the case
    /// is matched brute-force in the current z-space and merged with the
    /// tree hits, so matching after `push` is exact (if slower per query).
    pub fn push(&mut self, case: Case) {
        self.cases.push(case);
    }

    /// Eagerly drop cases older than `window` relative to `now` (the paper
    /// ages out old mappings over a rolling window to track seasonal
    /// drift). Discards the index when anything is removed; prefer
    /// [`advance_window`](KnowledgeBase::advance_window) on hot sliding
    /// loops, which amortizes the rebuild instead.
    pub fn age_out(&mut self, now: usize, window: usize) {
        self.age_floor = self.age_floor.max(now.saturating_sub(window));
        let floor = self.age_floor;
        let before = self.cases.len();
        self.cases.retain(|c| c.recorded_at >= floor);
        self.dead = 0;
        if self.cases.len() != before {
            self.tree = None;
            self.indexed = 0;
        }
    }

    /// Slide the rolling window with amortized maintenance (§Perf):
    /// out-of-window cases are tombstoned, freshly pushed cases stay in the
    /// brute-force tail, and the full reclaim + scaler refit + tree rebuild
    /// runs only once accumulated churn exceeds the configured fraction of
    /// the indexed set (`CARBONFLEX_KB_CHURN`, default 0.25; 0 restores the
    /// eager rebuild-every-slide behaviour). Matching stays exact over the
    /// live cases throughout; between rebuilds it uses the scaler fitted at
    /// the last rebuild.
    pub fn advance_window(&mut self, now: usize, window: usize) {
        self.age_floor = self.age_floor.max(now.saturating_sub(window));
        let floor = self.age_floor;
        // `dead` (for live()) counts every tombstone; the churn numerator
        // counts each case once — tombstoned *indexed* cases plus the whole
        // unindexed tail (a dead tail case is already tail churn).
        let dead_indexed =
            self.cases[..self.indexed].iter().filter(|c| c.recorded_at < floor).count();
        let dead_tail =
            self.cases[self.indexed..].iter().filter(|c| c.recorded_at < floor).count();
        self.dead = dead_indexed + dead_tail;
        let churn = (dead_indexed + self.pending()) as f64 / self.indexed.max(1) as f64;
        if self.tree.is_none() || churn > self.churn_fraction {
            self.rebuild();
        }
    }

    /// Reclaim tombstones and (re)build the KD-tree index (and refit the
    /// feature scaler) over all remaining cases.
    pub fn rebuild(&mut self) {
        if self.dead > 0 {
            let floor = self.age_floor;
            self.cases.retain(|c| c.recorded_at >= floor);
            self.dead = 0;
        }
        self.scaler = Scaler::fit(&self.cases);
        let scaler = self.scaler;
        self.tree =
            Some(KdTree::build(self.cases.iter().map(|c| scaler.apply(&c.state)).collect()));
        self.indexed = self.cases.len();
    }

    /// Persist as CSV: `recorded_at,state(;-separated),capacity,rho`.
    /// Tombstoned cases are persisted too (they are still in `cases`);
    /// call [`rebuild`](KnowledgeBase::rebuild) first for a compacted dump.
    pub fn save_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        // §Perf: one large buffer so trace-catalog-sized KBs flush in a
        // handful of syscalls instead of one per line.
        let mut f = std::io::BufWriter::with_capacity(1 << 16, std::fs::File::create(path)?);
        writeln!(f, "recorded_at,state,capacity,rho")?;
        for c in &self.cases {
            writeln!(f, "{},{},{},{:.6}", c.recorded_at, c.state.to_csv_cell(), c.capacity, c.rho)?;
        }
        f.flush()
    }

    /// Load the [`save_csv`](KnowledgeBase::save_csv) format. Single-pass
    /// field parsing (no per-line vector allocation) with the case vector
    /// pre-sized from the line count.
    pub fn load_csv(path: impl AsRef<Path>) -> std::io::Result<KnowledgeBase> {
        let src = std::fs::read_to_string(path)?;
        let mut cases = Vec::with_capacity(src.lines().count().saturating_sub(1));
        for (i, line) in src.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue;
            }
            let bad =
                || std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {}", i + 1));
            let mut fields = line.splitn(4, ',');
            let mut next = || fields.next().ok_or_else(bad);
            cases.push(Case {
                recorded_at: next()?.trim().parse().map_err(|_| bad())?,
                state: StateVector::from_csv_cell(next()?).ok_or_else(bad)?,
                capacity: next()?.trim().parse().map_err(|_| bad())?,
                // `splitn` leaves any extra commas in the last field, so a
                // 5-field line fails this parse exactly like before.
                rho: next()?.trim().parse().map_err(|_| bad())?,
            });
        }
        Ok(KnowledgeBase::from_cases(cases))
    }

    /// Match one query: exact top-k over the live cases, ascending by
    /// `(distance, case index)` — filtered tree hits over the indexed
    /// prefix merged with a brute-force pass over the unindexed tail, all
    /// in the z-space of the last-fitted scaler. An associated fn (not a
    /// method) so callers can borrow `hits` disjointly from the rest.
    #[allow(clippy::too_many_arguments)]
    fn hits_for(
        cases: &[Case],
        scaler: &Scaler,
        tree: Option<&KdTree>,
        indexed: usize,
        age_floor: usize,
        query: &StateVector,
        k: usize,
        hits: &mut Vec<Hit>,
    ) {
        hits.clear();
        if k == 0 {
            return;
        }
        let q = scaler.apply(query);
        if let Some(tree) = tree {
            tree.knn_filtered_into(&q, k, |i| cases[i].recorded_at >= age_floor, hits);
        }
        // Brute-force the unindexed tail in the same z-space and merge.
        // The distances are the same `dist2().sqrt()` the tree computes, so
        // the merged order (and any exact tie) is bitwise consistent.
        for (offset, case) in cases[indexed..].iter().enumerate() {
            if case.recorded_at < age_floor {
                continue;
            }
            let i = indexed + offset;
            let d = scaler.apply(&case.state).dist(&q);
            let pos = hits.partition_point(|h| h.dist < d || (h.dist == d && h.index < i));
            if pos < k {
                hits.insert(pos, Hit { index: i, dist: d });
                if hits.len() > k {
                    hits.pop();
                }
            }
        }
    }

    fn neighbor_of(&self, h: &Hit) -> Neighbor {
        let case = &self.cases[h.index];
        Neighbor {
            dist: h.dist,
            capacity: case.capacity,
            rho: case.rho,
            pressure: case.state.0[7],
        }
    }
}

impl Default for KnowledgeBase {
    fn default() -> Self {
        Self::new()
    }
}

impl Matcher for KnowledgeBase {
    fn top_k(&self, query: &StateVector, k: usize) -> Vec<Neighbor> {
        let mut hits = Vec::new();
        Self::hits_for(
            &self.cases,
            &self.scaler,
            self.tree.as_ref(),
            self.indexed,
            self.age_floor,
            query,
            k,
            &mut hits,
        );
        hits.iter().map(|h| self.neighbor_of(h)).collect()
    }

    fn top_k_into(&mut self, query: &StateVector, k: usize, out: &mut Vec<Neighbor>) {
        // §Perf: the hot path of the CarbonFlex decide loop — one filtered
        // flat-tree query into the reusable hit buffer, mapped into `out`.
        let KnowledgeBase { cases, scaler, tree, indexed, age_floor, hits, .. } = self;
        Self::hits_for(cases, scaler, tree.as_ref(), *indexed, *age_floor, query, k, hits);
        out.clear();
        out.reserve(self.hits.len());
        for h in &self.hits {
            out.push(self.neighbor_of(h));
        }
    }

    fn top_k_batch_into(
        &mut self,
        queries: &[StateVector],
        k: usize,
        out: &mut Vec<Neighbor>,
        offsets: &mut Vec<usize>,
    ) {
        out.clear();
        offsets.clear();
        offsets.reserve(queries.len() + 1);
        offsets.push(0);
        out.reserve(queries.len().saturating_mul(k.min(self.cases.len())));
        for query in queries {
            let KnowledgeBase { cases, scaler, tree, indexed, age_floor, hits, .. } = self;
            Self::hits_for(cases, scaler, tree.as_ref(), *indexed, *age_floor, query, k, hits);
            for h in &self.hits {
                out.push(self.neighbor_of(h));
            }
            offsets.push(out.len());
        }
    }

    fn len(&self) -> usize {
        self.cases.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, Config};
    use crate::util::rng::Rng;

    fn case(at: usize, ci: f64, cap: usize, rho: f64) -> Case {
        Case {
            recorded_at: at,
            state: StateVector::from_raw(ci, 0.0, 0.5, &[2, 1, 0], 0.6),
            capacity: cap,
            rho,
        }
    }

    #[test]
    fn match_returns_nearest_decision() {
        let mut kb = KnowledgeBase::new();
        kb.push(case(0, 100.0, 50, 0.8));
        kb.push(case(1, 600.0, 10, 1.01));
        kb.rebuild();
        let q = StateVector::from_raw(120.0, 0.0, 0.5, &[2, 1, 0], 0.6);
        let hits = kb.top_k(&q, 1);
        assert_eq!(hits[0].capacity, 50);
        assert!((hits[0].rho - 0.8).abs() < 1e-9);
    }

    #[test]
    fn indexed_matches_brute_force_in_z_space() {
        let mut kb = KnowledgeBase::new();
        for i in 0..50 {
            kb.push(case(i, 50.0 * i as f64 % 700.0, i, 0.5 + (i % 5) as f64 / 10.0));
        }
        kb.rebuild();
        let q = StateVector::from_raw(333.0, 0.0, 0.5, &[2, 1, 0], 0.6);
        let indexed = kb.top_k(&q, 5);
        // Brute force with the fitted scaler.
        let scaler = kb.scaler();
        let zq = scaler.apply(&q);
        let mut brute: Vec<f64> =
            kb.cases().iter().map(|c| scaler.apply(&c.state).dist(&zq)).collect();
        brute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (a, b) in indexed.iter().zip(&brute) {
            assert!((a.dist - b).abs() < 1e-9, "{} vs {}", a.dist, b);
        }
    }

    #[test]
    fn top_k_into_matches_top_k() {
        let mut kb = KnowledgeBase::new();
        for i in 0..60 {
            kb.push(case(i, (37 * i) as f64 % 700.0, i, 0.4 + (i % 7) as f64 / 10.0));
        }
        // Unindexed (brute-force tail) path first, then the KD-tree path.
        let q = StateVector::from_raw(250.0, 10.0, 0.4, &[3, 1, 0], 0.5);
        let mut buf = Vec::new();
        for rebuilt in [false, true] {
            if rebuilt {
                kb.rebuild();
            }
            let direct = kb.top_k(&q, 5);
            kb.top_k_into(&q, 5, &mut buf);
            assert_eq!(buf.len(), direct.len(), "rebuilt={rebuilt}");
            for (a, b) in buf.iter().zip(&direct) {
                assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "rebuilt={rebuilt}");
                assert_eq!(a.capacity, b.capacity);
                assert_eq!(a.rho.to_bits(), b.rho.to_bits());
            }
        }
    }

    #[test]
    fn pushed_tail_is_matched_without_rebuild() {
        // A case pushed after rebuild must be findable (brute-force merge)
        // even though the tree has not been rebuilt.
        let mut kb = KnowledgeBase::new();
        for i in 0..20 {
            kb.push(case(i, 30.0 * i as f64, 5, 0.5));
        }
        kb.rebuild();
        assert_eq!(kb.pending(), 0);
        kb.push(case(100, 120.0, 77, 0.9));
        assert_eq!(kb.pending(), 1);
        let q = StateVector::from_raw(120.0, 0.0, 0.5, &[2, 1, 0], 0.6);
        let hits = kb.top_k(&q, 1);
        assert_eq!(hits[0].capacity, 77, "tail case not merged: {hits:?}");
    }

    #[test]
    fn aging_drops_old_cases() {
        let mut kb = KnowledgeBase::new();
        for i in 0..10 {
            kb.push(case(i * 100, 200.0, i, 1.0));
        }
        kb.age_out(1000, 350);
        assert_eq!(kb.len(), 3); // recorded_at ≥ 650 → 700, 800, 900
        assert!(kb.cases().iter().all(|c| c.recorded_at + 350 >= 1000));
    }

    #[test]
    fn advance_window_defers_rebuild_until_churn_threshold() {
        let mut kb = KnowledgeBase::new();
        for i in 0..100 {
            kb.push(case(i, (13 * i) as f64 % 700.0, i % 20, 0.5));
        }
        kb.rebuild();
        kb.set_churn_fraction(0.25);
        let scaler_before = kb.scaler();
        // 10 dead + 0 pending over 100 indexed = 0.10 churn: lazy.
        kb.advance_window(110, 100);
        assert_eq!(kb.len(), 100, "lazy slide must not reclaim yet");
        assert_eq!(kb.live(), 90);
        assert_eq!(kb.scaler(), scaler_before, "lazy slide must not refit the scaler");
        // Tombstoned cases never match, even at distance zero.
        let dead_q = kb.cases()[0].state;
        let hits = kb.top_k(&dead_q, 100);
        assert_eq!(hits.len(), 90);
        // 30 dead crosses 0.25: reclaim + rebuild.
        kb.advance_window(130, 100);
        assert_eq!(kb.len(), 70, "churn over threshold must reclaim");
        assert_eq!(kb.live(), 70);
        assert_eq!(kb.pending(), 0);
        assert!(kb.cases().iter().all(|c| c.recorded_at >= 30));
    }

    #[test]
    fn advance_window_with_zero_churn_is_eager() {
        let mut kb = KnowledgeBase::new();
        for i in 0..40 {
            kb.push(case(i, (31 * i) as f64 % 700.0, i, 0.5));
        }
        kb.rebuild();
        kb.set_churn_fraction(0.0);
        kb.push(case(50, 200.0, 9, 0.5));
        kb.advance_window(45, 40);
        // Any churn (1 dead would do; here 1 pending) rebuilds immediately.
        assert_eq!(kb.pending(), 0);
        assert_eq!(kb.live(), kb.len());
        assert!(kb.cases().iter().all(|c| c.recorded_at >= 5));
    }

    #[test]
    fn churn_fraction_env_parsing() {
        // No process-global env mutation in tests: only assert the default
        // when CARBONFLEX_KB_CHURN is genuinely unset in this environment.
        if std::env::var_os("CARBONFLEX_KB_CHURN").is_none() {
            assert_eq!(churn_fraction_from_env(), DEFAULT_CHURN_FRACTION);
        }
        let mut kb = KnowledgeBase::new();
        kb.set_churn_fraction(-3.0);
        for i in 0..4 {
            kb.push(case(i, 100.0 * i as f64, i, 0.5));
        }
        kb.rebuild();
        kb.push(case(9, 50.0, 1, 0.5));
        // Clamped to 0 → eager.
        kb.advance_window(9, 100);
        assert_eq!(kb.pending(), 0);
    }

    /// Property: after an arbitrary push / rebuild / advance_window
    /// history, batched == single-query == brute force over the live cases
    /// in the last-fitted z-space, ties by case index, k > len included.
    #[test]
    fn property_matching_stays_exact_under_lazy_maintenance() {
        fn rand_case(rng: &mut Rng, at: usize) -> Case {
            Case {
                recorded_at: at,
                // Coarse grid so exact-distance ties occur.
                state: StateVector::from_raw(
                    rng.below(5) as f64 * 150.0,
                    0.0,
                    rng.below(3) as f64 * 0.5,
                    &[rng.below(3), rng.below(3), 0],
                    0.5,
                ),
                capacity: rng.below(30),
                rho: rng.below(4) as f64 * 0.25,
            }
        }
        check(
            "kb batch == single == brute under lazy maintenance",
            Config { cases: 64, seed: 0x5EED_CAFE },
            |rng| {
                let initial = 2 + rng.below(30);
                let pushed = rng.below(10);
                let window = 5 + rng.below(30);
                let now = rng.below(60);
                let k = 1 + rng.below(initial + pushed + 4);
                let queries: Vec<StateVector> = (0..1 + rng.below(3))
                    .map(|_| {
                        StateVector::from_raw(
                            rng.below(5) as f64 * 150.0,
                            0.0,
                            rng.below(3) as f64 * 0.5,
                            &[rng.below(3), rng.below(3), 0],
                            0.5,
                        )
                    })
                    .collect();
                let seed = rng.next_u64();
                (initial, pushed, window, now, k, queries, seed)
            },
            |&(initial, pushed, window, now, k, ref queries, seed)| {
                let mut rng = Rng::new(seed);
                let mut kb = KnowledgeBase::new();
                kb.set_churn_fraction(0.3);
                for i in 0..initial {
                    kb.push(rand_case(&mut rng, i));
                }
                kb.rebuild();
                for i in 0..pushed {
                    kb.push(rand_case(&mut rng, initial + i));
                }
                kb.advance_window(now, window);
                let floor = now.saturating_sub(window);
                let scaler = kb.scaler();
                let mut batch_out = Vec::new();
                let mut batch_offsets = Vec::new();
                kb.top_k_batch_into(queries, k, &mut batch_out, &mut batch_offsets);
                let mut single = Vec::new();
                for (qi, q) in queries.iter().enumerate() {
                    // Brute force over live cases with the fitted scaler.
                    let zq = scaler.apply(q);
                    let mut want: Vec<(f64, usize)> = kb
                        .cases()
                        .iter()
                        .enumerate()
                        .filter(|(_, c)| c.recorded_at >= floor)
                        .map(|(i, c)| (scaler.apply(&c.state).dist(&zq), i))
                        .collect();
                    want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                    want.truncate(k);

                    kb.top_k_into(q, k, &mut single);
                    let seg = &batch_out[batch_offsets[qi]..batch_offsets[qi + 1]];
                    if single.len() != want.len() || seg.len() != want.len() {
                        return Err(format!(
                            "query {qi}: lens single={} batch={} brute={}",
                            single.len(),
                            seg.len(),
                            want.len()
                        ));
                    }
                    for (j, &(d, i)) in want.iter().enumerate() {
                        let c = &kb.cases()[i];
                        for (label, got) in [("single", &single[j]), ("batch", &seg[j])] {
                            if got.dist.to_bits() != d.to_bits()
                                || got.capacity != c.capacity
                                || got.rho.to_bits() != c.rho.to_bits()
                            {
                                return Err(format!(
                                    "query {qi} hit {j} ({label}): got {got:?} want case {i} \
                                     dist {d}"
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: tombstone + churn-threshold maintenance is pure
    /// bookkeeping. For an arbitrary interleaving of pushes and window
    /// slides and any churn fraction (covering the `CARBONFLEX_KB_CHURN`
    /// settings the CI matrix exercises: 0.0 eager, 0.25 default, 1.0
    /// maximally lazy, plus random values):
    /// (a) the lazy KB tracks the live-case set of an eagerly-rebuilt twin
    ///     exactly after every slide,
    /// (b) matching stays exact over the live set in the last-fitted
    ///     z-space (ties by case index), and
    /// (c) once rebuilt, the lazy KB is bitwise identical — cases, fitted
    ///     scaler, and matches, ties included — to a fresh
    ///     [`KnowledgeBase::from_cases`] over the surviving cases.
    #[test]
    fn property_advance_window_matches_fresh_rebuild() {
        fn rand_case(rng: &mut Rng, at: usize) -> Case {
            Case {
                recorded_at: at,
                // Coarse grid so exact-distance ties occur.
                state: StateVector::from_raw(
                    rng.below(5) as f64 * 150.0,
                    0.0,
                    rng.below(3) as f64 * 0.5,
                    &[rng.below(3), rng.below(3), 0],
                    0.5,
                ),
                capacity: rng.below(30),
                rho: rng.below(4) as f64 * 0.25,
            }
        }
        #[derive(Debug, Clone, Copy)]
        enum Op {
            Push { at: usize },
            Advance { now: usize, window: usize },
        }
        check(
            "advance_window == fresh rebuild",
            Config { cases: 64, seed: 0xA6E0_CAFE },
            |rng| {
                let churn = match rng.below(4) {
                    0 => 0.0,
                    1 => 0.25,
                    2 => 1.0,
                    _ => rng.below(100) as f64 / 100.0,
                };
                let ops: Vec<Op> = (0..3 + rng.below(24))
                    .map(|_| {
                        if rng.below(3) == 0 {
                            Op::Advance { now: rng.below(80), window: 5 + rng.below(40) }
                        } else {
                            Op::Push { at: rng.below(60) }
                        }
                    })
                    .collect();
                let k = 1 + rng.below(8);
                let seed = rng.next_u64();
                (churn, ops, k, seed)
            },
            |&(churn, ref ops, k, seed)| {
                let mut rng = Rng::new(seed);
                let mut lazy = KnowledgeBase::new();
                lazy.set_churn_fraction(churn);
                // Eager twin: rebuilds on every slide (the historical
                // behaviour the lazy path must be indistinguishable from).
                let mut eager = KnowledgeBase::new();
                eager.set_churn_fraction(0.0);
                let mut floor = 0usize; // shadow of the rolling window
                for &op in ops {
                    match op {
                        Op::Push { at } => {
                            let c = rand_case(&mut rng, at);
                            lazy.push(c.clone());
                            eager.push(c);
                        }
                        Op::Advance { now, window } => {
                            lazy.advance_window(now, window);
                            eager.advance_window(now, window);
                            floor = floor.max(now.saturating_sub(window));
                            // (a) live bookkeeping agrees with the eager
                            // twin and the shadow floor.
                            if lazy.live() != eager.live() {
                                return Err(format!(
                                    "live diverged: lazy {} vs eager {}",
                                    lazy.live(),
                                    eager.live()
                                ));
                            }
                            let shadow_live = lazy
                                .cases()
                                .iter()
                                .filter(|c| c.recorded_at >= floor)
                                .count();
                            if lazy.live() != shadow_live {
                                return Err(format!(
                                    "live() {} != shadow count {shadow_live}",
                                    lazy.live()
                                ));
                            }
                        }
                    }
                    // (b) matching is exact over the live set in the
                    // last-fitted z-space after every op, ties by index.
                    let q = rand_case(&mut rng, 0).state;
                    let scaler = lazy.scaler();
                    let zq = scaler.apply(&q);
                    let mut want: Vec<(f64, usize)> = lazy
                        .cases()
                        .iter()
                        .enumerate()
                        .filter(|(_, c)| c.recorded_at >= floor)
                        .map(|(i, c)| (scaler.apply(&c.state).dist(&zq), i))
                        .collect();
                    want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                    want.truncate(k);
                    let got = lazy.top_k(&q, k);
                    if got.len() != want.len() {
                        return Err(format!(
                            "hit count: got {} want {}",
                            got.len(),
                            want.len()
                        ));
                    }
                    for (j, (&(d, i), g)) in want.iter().zip(&got).enumerate() {
                        let c = &lazy.cases()[i];
                        if g.dist.to_bits() != d.to_bits()
                            || g.capacity != c.capacity
                            || g.rho.to_bits() != c.rho.to_bits()
                        {
                            return Err(format!(
                                "hit {j}: got {g:?} want case {i} dist {d}"
                            ));
                        }
                    }
                }
                // (c) after a forced rebuild the lazy KB is bitwise a fresh
                // build over the surviving cases — and carries exactly the
                // eager twin's case set. One final synchronized slide first:
                // it re-tombstones any stale cases pushed since the last
                // slide in BOTH twins (rebuild() only reclaims tombstones
                // counted at the latest advance_window, so without this the
                // two could legitimately disagree on such stragglers).
                lazy.advance_window(floor, 0);
                eager.advance_window(floor, 0);
                lazy.rebuild();
                if lazy.len() != eager.len() {
                    return Err(format!(
                        "post-rebuild case count: lazy {} vs eager {}",
                        lazy.len(),
                        eager.len()
                    ));
                }
                for (a, b) in lazy.cases().iter().zip(eager.cases()) {
                    if a != b {
                        return Err(format!("post-rebuild cases diverged: {a:?} vs {b:?}"));
                    }
                }
                let fresh = KnowledgeBase::from_cases(lazy.cases().to_vec());
                if lazy.scaler() != fresh.scaler() {
                    return Err("rebuilt scaler != fresh-fit scaler".into());
                }
                for probe in 0..4 {
                    let q = rand_case(&mut rng, probe).state;
                    let (a, b) = (lazy.top_k(&q, k), fresh.top_k(&q, k));
                    if a.len() != b.len() {
                        return Err(format!(
                            "probe {probe}: rebuilt {} hits vs fresh {}",
                            a.len(),
                            b.len()
                        ));
                    }
                    for (x, y) in a.iter().zip(&b) {
                        if x.dist.to_bits() != y.dist.to_bits()
                            || x.capacity != y.capacity
                            || x.rho.to_bits() != y.rho.to_bits()
                        {
                            return Err(format!(
                                "probe {probe}: rebuilt {x:?} vs fresh {y:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn csv_roundtrip() {
        let mut kb = KnowledgeBase::new();
        for i in 0..20 {
            kb.push(case(i, 37.0 * i as f64, 150 - i, 0.25 + i as f64 / 100.0));
        }
        let dir = std::env::temp_dir().join("carbonflex_kb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.csv");
        kb.save_csv(&path).unwrap();
        let loaded = KnowledgeBase::load_csv(&path).unwrap();
        assert_eq!(loaded.len(), 20);
        for (a, b) in kb.cases().iter().zip(loaded.cases()) {
            assert_eq!(a.recorded_at, b.recorded_at);
            assert_eq!(a.capacity, b.capacity);
            assert!((a.rho - b.rho).abs() < 1e-5);
        }
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("carbonflex_kb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        for bad in [
            "recorded_at,state,capacity,rho\n1,notastate,5,0.5\n",
            "recorded_at,state,capacity,rho\n1,0;0;0;0;0;0;0;0,5\n",
            "recorded_at,state,capacity,rho\n1,0;0;0;0;0;0;0;0,5,0.5,extra\n",
        ] {
            std::fs::write(&path, bad).unwrap();
            assert!(KnowledgeBase::load_csv(&path).is_err(), "accepted: {bad:?}");
        }
    }
}
