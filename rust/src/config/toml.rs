//! TOML-subset parser (no `toml`/`serde` crates offline).
//!
//! Supports the subset CarbonFlex's config files use:
//! - `[table]` and dotted `[table.sub]` headers
//! - `[[array-of-tables]]` headers
//! - `key = value` with basic strings (`"..."`), integers, floats, booleans,
//!   and homogeneous arrays `[v1, v2, ...]` (nesting allowed)
//! - `#` comments and blank lines
//!
//! Values parse into [`Value`]; [`Value::get_path`] provides dotted lookup.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Numeric coercion: ints widen to f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_table().and_then(|t| t.get(key))
    }
    /// Dotted-path lookup, e.g. `get_path("cluster.capacity")`.
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, TomlError> {
    Err(TomlError { line, msg: msg.into() })
}

/// Parse a TOML document into a root table.
pub fn parse(src: &str) -> Result<Value, TomlError> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    // Path of the currently-open table ([] = root).
    let mut current: Vec<String> = Vec::new();

    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            // Array-of-tables: append a fresh table to the array at `inner`.
            let path: Vec<String> = inner.split('.').map(|p| p.trim().to_string()).collect();
            if path.iter().any(|p| p.is_empty()) {
                return err(lineno, "empty table name");
            }
            let arr = ensure_array(&mut root, &path, lineno)?;
            arr.push(Value::Table(BTreeMap::new()));
            // The traversal in `insert`/`ensure_table` resolves an array
            // segment to its most recently opened table, so the plain path
            // addresses the new element.
            current = path;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let path: Vec<String> = inner.split('.').map(|p| p.trim().to_string()).collect();
            if path.iter().any(|p| p.is_empty()) {
                return err(lineno, "empty table name");
            }
            ensure_table(&mut root, &path, lineno)?;
            current = path;
        } else if let Some(eq) = find_eq(line) {
            let key = line[..eq].trim();
            if key.is_empty() {
                return err(lineno, "empty key");
            }
            let vsrc = line[eq + 1..].trim();
            let value = parse_value(vsrc, lineno)?;
            insert(&mut root, &current, key, value, lineno)?;
        } else {
            return err(lineno, format!("unrecognized line: '{line}'"));
        }
    }
    Ok(Value::Table(root))
}

/// Find the `=` separating key from value, ignoring any inside quotes.
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur.entry(part.clone()).or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            Value::Arr(a) => match a.last_mut() {
                Some(Value::Table(t)) => t,
                _ => return err(lineno, format!("'{part}' is not a table")),
            },
            _ => return err(lineno, format!("'{part}' is not a table")),
        };
    }
    Ok(cur)
}

fn ensure_array<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut Vec<Value>, TomlError> {
    let (last, prefix) = path.split_last().unwrap();
    let parent = ensure_table(root, prefix, lineno)?;
    let entry = parent.entry(last.clone()).or_insert_with(|| Value::Arr(vec![]));
    match entry {
        Value::Arr(a) => Ok(a),
        _ => err(lineno, format!("'{last}' is not an array of tables")),
    }
}

fn insert(
    root: &mut BTreeMap<String, Value>,
    current: &[String],
    key: &str,
    value: Value,
    lineno: usize,
) -> Result<(), TomlError> {
    // Resolve the current table, traversing synthetic array indices.
    let mut cur: &mut BTreeMap<String, Value> = root;
    for part in current {
        let next = match cur.get_mut(part.as_str()) {
            Some(v) => v,
            None => return err(lineno, format!("missing table '{part}'")),
        };
        cur = match next {
            Value::Table(t) => t,
            // An array segment addresses its most recently opened table.
            Value::Arr(a) => match a.last_mut() {
                Some(Value::Table(t)) => t,
                _ => return err(lineno, format!("'{part}' has no open table")),
            },
            _ => return err(lineno, format!("'{part}' is not a table")),
        };
    }
    if cur.contains_key(key) {
        return err(lineno, format!("duplicate key '{key}'"));
    }
    cur.insert(key.to_string(), value);
    Ok(())
}

fn parse_value(src: &str, lineno: usize) -> Result<Value, TomlError> {
    let src = src.trim();
    if src.is_empty() {
        return err(lineno, "missing value");
    }
    if let Some(rest) = src.strip_prefix('"') {
        let Some(end) = rest.find('"') else { return err(lineno, "unterminated string") };
        if !rest[end + 1..].trim().is_empty() {
            return err(lineno, "trailing characters after string");
        }
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if src == "true" {
        return Ok(Value::Bool(true));
    }
    if src == "false" {
        return Ok(Value::Bool(false));
    }
    if src.starts_with('[') {
        return parse_array(src, lineno);
    }
    // Number: int if no '.', 'e' or 'E'.
    let clean = src.replace('_', "");
    if !clean.contains('.') && !clean.contains(['e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    err(lineno, format!("cannot parse value '{src}'"))
}

fn parse_array(src: &str, lineno: usize) -> Result<Value, TomlError> {
    // Split top-level commas, respecting nested brackets and strings.
    let inner = src
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| TomlError { line: lineno, msg: "unterminated array".into() })?;
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                let piece = inner[start..i].trim();
                if !piece.is_empty() {
                    items.push(parse_value(piece, lineno)?);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    let piece = inner[start..].trim();
    if !piece.is_empty() {
        items.push(parse_value(piece, lineno)?);
    }
    Ok(Value::Arr(items))
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Table(t) => {
                write!(f, "{{")?;
                for (i, (k, v)) in t.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k} = {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_keys() {
        let v = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(1));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn tables_and_dotted() {
        let src = "[cluster]\ncapacity = 150\n[cluster.power]\nwatts = 100.0\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get_path("cluster.capacity").unwrap().as_int(), Some(150));
        assert_eq!(v.get_path("cluster.power.watts").unwrap().as_f64(), Some(100.0));
    }

    #[test]
    fn arrays() {
        let v = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nnested = [[1, 2], [3]]\n").unwrap();
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("ys").unwrap().as_arr().unwrap()[1].as_str(), Some("b"));
        let nested = v.get("nested").unwrap().as_arr().unwrap();
        assert_eq!(nested[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn array_of_tables() {
        let src = "[[queue]]\nname = \"short\"\ndelay = 6\n[[queue]]\nname = \"long\"\ndelay = 48\n";
        let v = parse(src).unwrap();
        let queues = v.get("queue").unwrap().as_arr().unwrap();
        assert_eq!(queues.len(), 2);
        assert_eq!(queues[0].get("name").unwrap().as_str(), Some("short"));
        assert_eq!(queues[1].get("delay").unwrap().as_int(), Some(48));
    }

    #[test]
    fn comments_and_blanks() {
        let src = "# header\n\na = 1 # trailing\ns = \"with # inside\"\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(1));
        assert_eq!(v.get("s").unwrap().as_str(), Some("with # inside"));
    }

    #[test]
    fn underscored_numbers() {
        let v = parse("big = 1_000_000\n").unwrap();
        assert_eq!(v.get("big").unwrap().as_int(), Some(1_000_000));
    }

    #[test]
    fn errors() {
        assert!(parse("= 1\n").is_err());
        assert!(parse("a = \n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("??\n").is_err());
        assert!(parse("a = \"unterminated\n").is_err());
    }

    #[test]
    fn negative_and_exp_numbers() {
        let v = parse("a = -5\nb = -2.5\nc = 1e-3\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(-5));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(-2.5));
        assert_eq!(v.get("c").unwrap().as_f64(), Some(0.001));
    }
}
