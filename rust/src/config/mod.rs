//! Configuration system: a TOML-subset parser plus the typed
//! [`schema::ExperimentConfig`] every launcher entrypoint consumes.

pub mod schema;
pub mod toml;

pub use schema::{
    default_queues, ConfigError, DagShape, ElasticityScenario, ExperimentConfig, Hardware,
    QueueConfig, ServiceConfig, ShedPolicy, TraceFamily,
};
