//! Typed experiment configuration extracted from TOML.
//!
//! A config file fully pins an experiment: the cluster (capacity, hardware
//! class), the carbon region, the workload trace family and its knobs, the
//! queue/slack setup, the policy under test, and the RNG seed. Every figure
//! in `configs/` is one of these plus a sweep axis.

use std::path::Path;

use crate::config::toml::{self, Value};

/// Configuration error.
#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse(toml::TomlError),
    Field(String, String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "io error reading config: {e}"),
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Field(field, msg) => write!(f, "config field '{field}': {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            ConfigError::Parse(e) => Some(e),
            ConfigError::Field(..) => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl From<toml::TomlError> for ConfigError {
    fn from(e: toml::TomlError) -> Self {
        ConfigError::Parse(e)
    }
}

fn field_err(field: &str, msg: impl Into<String>) -> ConfigError {
    ConfigError::Field(field.to_string(), msg.into())
}

/// Hardware class of the homogeneous cluster (paper §6.1: C8 CPU / G6 GPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hardware {
    Cpu,
    Gpu,
}

impl Hardware {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Ok(Hardware::Cpu),
            "gpu" => Ok(Hardware::Gpu),
            other => Err(field_err("cluster.hardware", format!("unknown hardware '{other}'"))),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Hardware::Cpu => "cpu",
            Hardware::Gpu => "gpu",
        }
    }
}

/// Workload trace family (paper §6.1: Azure, Alibaba-PAI, SURF Lisa).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFamily {
    AzureLike,
    AlibabaLike,
    SurfLike,
}

impl TraceFamily {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "azure" | "azure-like" => Ok(TraceFamily::AzureLike),
            "alibaba" | "alibaba-like" | "pai" => Ok(TraceFamily::AlibabaLike),
            "surf" | "surf-like" | "lisa" => Ok(TraceFamily::SurfLike),
            other => Err(field_err("workload.trace", format!("unknown trace family '{other}'"))),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceFamily::AzureLike => "azure",
            TraceFamily::AlibabaLike => "alibaba",
            TraceFamily::SurfLike => "surf",
        }
    }
}

/// Elasticity scenario (Fig. 10): which profiles jobs draw.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElasticityScenario {
    /// Random assignment from the Table 3 catalog (the paper's default).
    Mix,
    /// All jobs highly scalable.
    High,
    /// All jobs moderately scalable.
    Moderate,
    /// All jobs poorly scalable.
    Low,
    /// Jobs cannot scale (k_min == k_max); provisioning-only benefits.
    NoScaling,
}

impl ElasticityScenario {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "mix" => Ok(Self::Mix),
            "high" => Ok(Self::High),
            "moderate" => Ok(Self::Moderate),
            "low" => Ok(Self::Low),
            "noscaling" | "no-scaling" | "none" => Ok(Self::NoScaling),
            other => Err(field_err("workload.elasticity", format!("unknown scenario '{other}'"))),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Mix => "mix",
            Self::High => "high",
            Self::Moderate => "moderate",
            Self::Low => "low",
            Self::NoScaling => "noscaling",
        }
    }
}

/// DAG topology tracegen wires into a generated trace (the `dag_shape`
/// sweep axis). [`DagShape::None`] is the degenerate zero-edge case: the
/// generator does not touch its RNG stream for it, so flat traces stay
/// bitwise identical to the pre-DAG generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DagShape {
    /// No dependency edges (flat, independent jobs — the default).
    None,
    /// Linear chains: jobs partitioned into pipelines, each stage depending
    /// on its predecessor.
    Chains,
    /// Fan-out trees: one root per group, every other member depends on it.
    Fanout,
    /// Map-reduce: per group, independent maps plus one final reduce
    /// depending on every map.
    MapReduce,
    /// Random DAGs: each job independently draws 1–2 earlier parents.
    Random,
}

impl DagShape {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "flat" => Ok(Self::None),
            "chains" | "chain" => Ok(Self::Chains),
            "fanout" | "fan-out" => Ok(Self::Fanout),
            "mapreduce" | "map-reduce" => Ok(Self::MapReduce),
            "random" => Ok(Self::Random),
            other => Err(field_err(
                "workload.dag_shape",
                format!("unknown dag shape '{other}' (none, chains, fanout, mapreduce, random)"),
            )),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Chains => "chains",
            Self::Fanout => "fanout",
            Self::MapReduce => "mapreduce",
            Self::Random => "random",
        }
    }
}

/// A submission queue: jobs with base-length in `(min_len, max_len]` hours get
/// slack `delay_hours` (paper default: short ≤2h → 6h, medium ≤12h → 24h,
/// long → 48h).
#[derive(Debug, Clone, PartialEq)]
pub struct QueueConfig {
    pub name: String,
    pub max_len_hours: f64,
    pub delay_hours: f64,
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Maximum allowed cluster capacity M (servers).
    pub capacity: usize,
    pub hardware: Hardware,
    /// Carbon region key (see `carbon::synth::Region`).
    pub region: String,
    pub trace: TraceFamily,
    pub elasticity: ElasticityScenario,
    /// Target mean utilization used to scale arrival rate (paper: ~50%).
    pub target_utilization: f64,
    /// Evaluation horizon in hours (paper: one week = 168).
    pub horizon_hours: usize,
    /// Historical learning window in hours (paper: two weeks).
    pub history_hours: usize,
    /// Extra replay offsets for the learning phase (paper: multiple start times).
    pub replay_offsets: usize,
    pub queues: Vec<QueueConfig>,
    /// Arrival-rate multiplier for distribution-shift studies (Fig. 13).
    pub arrival_scale: f64,
    /// Job-length multiplier for distribution-shift studies (Fig. 13).
    pub length_scale: f64,
    /// Dependency topology tracegen imposes on the generated jobs
    /// ([`DagShape::None`] = flat, bitwise identical to the pre-DAG traces).
    pub dag_shape: DagShape,
    /// Override every queue's slack with this many hours (Fig. 9 sweeps).
    pub uniform_delay_hours: Option<f64>,
    /// k=5 nearest neighbours for the CBR match (paper §5).
    pub knn_k: usize,
    /// Alg. 2 fallback knobs: violation tolerance ε and distance bound δ.
    pub violation_tolerance: f64,
    pub distance_bound: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 42,
            capacity: 150,
            hardware: Hardware::Cpu,
            region: "south-australia".into(),
            trace: TraceFamily::AzureLike,
            elasticity: ElasticityScenario::Mix,
            target_utilization: 0.5,
            horizon_hours: 168,
            history_hours: 336,
            replay_offsets: 8,
            queues: default_queues(),
            arrival_scale: 1.0,
            length_scale: 1.0,
            dag_shape: DagShape::None,
            uniform_delay_hours: None,
            knn_k: 5,
            violation_tolerance: 0.2,
            distance_bound: 1.5,
        }
    }
}

/// The paper's three length-based queues (§6.1).
pub fn default_queues() -> Vec<QueueConfig> {
    vec![
        QueueConfig { name: "short".into(), max_len_hours: 2.0, delay_hours: 6.0 },
        QueueConfig { name: "medium".into(), max_len_hours: 12.0, delay_hours: 24.0 },
        QueueConfig { name: "long".into(), max_len_hours: f64::INFINITY, delay_hours: 48.0 },
    ]
}

impl ExperimentConfig {
    /// Load and validate from a TOML file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        let src = std::fs::read_to_string(path)?;
        Self::from_toml_str(&src)
    }

    /// Parse from TOML source. Missing fields take defaults; present fields
    /// are validated.
    pub fn from_toml_str(src: &str) -> Result<Self, ConfigError> {
        let root = toml::parse(src)?;
        let mut cfg = ExperimentConfig::default();

        if let Some(v) = root.get_path("experiment.name") {
            cfg.name = req_str(v, "experiment.name")?.to_string();
        }
        if let Some(v) = root.get_path("experiment.seed") {
            cfg.seed = req_int(v, "experiment.seed")? as u64;
        }
        if let Some(v) = root.get_path("experiment.horizon_hours") {
            cfg.horizon_hours = pos_usize(v, "experiment.horizon_hours")?;
        }
        if let Some(v) = root.get_path("experiment.history_hours") {
            cfg.history_hours = pos_usize(v, "experiment.history_hours")?;
        }
        if let Some(v) = root.get_path("experiment.replay_offsets") {
            cfg.replay_offsets = pos_usize(v, "experiment.replay_offsets")?;
        }
        if let Some(v) = root.get_path("cluster.capacity") {
            cfg.capacity = pos_usize(v, "cluster.capacity")?;
        }
        if let Some(v) = root.get_path("cluster.hardware") {
            cfg.hardware = Hardware::parse(req_str(v, "cluster.hardware")?)?;
        }
        if let Some(v) = root.get_path("cluster.region") {
            cfg.region = req_str(v, "cluster.region")?.to_string();
        }
        if let Some(v) = root.get_path("workload.trace") {
            cfg.trace = TraceFamily::parse(req_str(v, "workload.trace")?)?;
        }
        if let Some(v) = root.get_path("workload.elasticity") {
            cfg.elasticity = ElasticityScenario::parse(req_str(v, "workload.elasticity")?)?;
        }
        if let Some(v) = root.get_path("workload.target_utilization") {
            cfg.target_utilization = unit_f64(v, "workload.target_utilization")?;
        }
        if let Some(v) = root.get_path("workload.arrival_scale") {
            cfg.arrival_scale = pos_f64(v, "workload.arrival_scale")?;
        }
        if let Some(v) = root.get_path("workload.length_scale") {
            cfg.length_scale = pos_f64(v, "workload.length_scale")?;
        }
        if let Some(v) = root.get_path("workload.dag_shape") {
            cfg.dag_shape = DagShape::parse(req_str(v, "workload.dag_shape")?)?;
        }
        if let Some(v) = root.get_path("scheduler.uniform_delay_hours") {
            cfg.uniform_delay_hours = Some(nonneg_f64(v, "scheduler.uniform_delay_hours")?);
        }
        if let Some(v) = root.get_path("scheduler.knn_k") {
            cfg.knn_k = pos_usize(v, "scheduler.knn_k")?;
        }
        if let Some(v) = root.get_path("scheduler.violation_tolerance") {
            cfg.violation_tolerance = unit_f64(v, "scheduler.violation_tolerance")?;
        }
        if let Some(v) = root.get_path("scheduler.distance_bound") {
            cfg.distance_bound = pos_f64(v, "scheduler.distance_bound")?;
        }
        if let Some(v) = root.get("queue") {
            let arr = v
                .as_arr()
                .ok_or_else(|| field_err("queue", "expected array of [[queue]] tables"))?;
            let mut queues = Vec::new();
            for (i, q) in arr.iter().enumerate() {
                let name = q
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| field_err(&format!("queue[{i}].name"), "missing string"))?
                    .to_string();
                let max_len_hours = match q.get("max_len_hours") {
                    Some(v) => pos_f64(v, &format!("queue[{i}].max_len_hours"))?,
                    None => f64::INFINITY,
                };
                let delay_hours = nonneg_f64(
                    q.get("delay_hours")
                        .ok_or_else(|| field_err(&format!("queue[{i}].delay_hours"), "missing"))?,
                    &format!("queue[{i}].delay_hours"),
                )?;
                queues.push(QueueConfig { name, max_len_hours, delay_hours });
            }
            if queues.is_empty() {
                return Err(field_err("queue", "at least one queue required"));
            }
            cfg.queues = queues;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation beyond per-field checks.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.capacity == 0 {
            return Err(field_err("cluster.capacity", "must be > 0"));
        }
        if self.horizon_hours < 24 {
            return Err(field_err("experiment.horizon_hours", "must be >= 24"));
        }
        if self.history_hours < self.horizon_hours {
            return Err(field_err(
                "experiment.history_hours",
                "history window must be >= evaluation horizon",
            ));
        }
        if self.queues.len() > crate::sched::MAX_QUEUES {
            return Err(field_err(
                "queue",
                "at most 8 queues are supported (engine queue features are inline arrays)",
            ));
        }
        let mut prev = 0.0;
        for q in &self.queues {
            if q.max_len_hours <= prev {
                return Err(field_err(
                    "queue",
                    "queues must have strictly increasing max_len_hours",
                ));
            }
            prev = q.max_len_hours;
        }
        if !self.queues.last().map(|q| q.max_len_hours.is_infinite()).unwrap_or(false) {
            return Err(field_err("queue", "last queue must be unbounded (omit max_len_hours)"));
        }
        Ok(())
    }

    /// Slack (hours) for a job of base length `len_hours`, honoring the
    /// uniform-delay override used by the Fig. 9 sweep.
    pub fn slack_for_length(&self, len_hours: f64) -> f64 {
        if let Some(d) = self.uniform_delay_hours {
            return d;
        }
        for q in &self.queues {
            if len_hours <= q.max_len_hours {
                return q.delay_hours;
            }
        }
        self.queues.last().map(|q| q.delay_hours).unwrap_or(0.0)
    }

    /// This config with the Fig. 13 distribution-shift knobs reset. The
    /// learning history is always generated at the unshifted scale (the
    /// shift applies to the evaluation window only), so a shifted config
    /// measures the paper's learn/eval mismatch rather than re-learning on
    /// the shifted distribution.
    pub fn unshifted_history(&self) -> ExperimentConfig {
        let mut cfg = self.clone();
        cfg.arrival_scale = 1.0;
        cfg.length_scale = 1.0;
        // The learning history also stays flat: the oracle replay that
        // builds the knowledge base learns provisioning/threshold mappings
        // from independent jobs, and a `dag_shape` cell measures how those
        // learned decisions transfer to precedence-constrained evaluation
        // workloads (mirroring the Fig. 13 learn/eval-mismatch design).
        cfg.dag_shape = DagShape::None;
        cfg
    }

    /// Index of the queue a job of this length lands in.
    pub fn queue_for_length(&self, len_hours: f64) -> usize {
        for (i, q) in self.queues.iter().enumerate() {
            if len_hours <= q.max_len_hours {
                return i;
            }
        }
        self.queues.len() - 1
    }
}

/// Backpressure shed policy for the traffic-serving coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// When the pending bound is hit, reject the incoming submission
    /// (`queue_full`).
    RejectNewest,
    /// When the bound is hit, shed submissions destined for delay-tolerant
    /// queues (`shed`); only queue 0 (least slack) is admitted over the
    /// bound.
    RejectLowestQueue,
}

impl ShedPolicy {
    pub const ALL: [ShedPolicy; 2] = [ShedPolicy::RejectNewest, ShedPolicy::RejectLowestQueue];

    pub fn as_str(&self) -> &'static str {
        match self {
            ShedPolicy::RejectNewest => "reject-newest",
            ShedPolicy::RejectLowestQueue => "reject-lowest-queue",
        }
    }

    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "reject-newest" | "newest" => Some(ShedPolicy::RejectNewest),
            "reject-lowest-queue" | "lowest-queue" => Some(ShedPolicy::RejectLowestQueue),
            _ => None,
        }
    }
}

/// Service limits for the traffic-serving coordinator, read from an optional
/// `[service]` table (which [`ExperimentConfig::from_toml_str`] ignores, so
/// one file can configure both the experiment and the service tier).
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Bound on jobs waiting + running in one coordinator; submissions past
    /// it are rejected per the shed policy.
    pub max_pending: usize,
    /// Largest accepted `submit_batch` envelope.
    pub max_batch: usize,
    pub shed: ShedPolicy,
    /// Default shard count for `serve`/`serve-bench` (one coordinator per
    /// region).
    pub shards: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_pending: 4096,
            max_batch: 1024,
            shed: ShedPolicy::RejectNewest,
            shards: 1,
        }
    }
}

impl ServiceConfig {
    pub fn load(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        let src = std::fs::read_to_string(path)?;
        Self::from_toml_str(&src)
    }

    /// Parse the `[service]` table from TOML source; missing fields take
    /// defaults.
    pub fn from_toml_str(src: &str) -> Result<Self, ConfigError> {
        let root = toml::parse(src)?;
        let mut cfg = ServiceConfig::default();
        if let Some(v) = root.get_path("service.max_pending") {
            cfg.max_pending = pos_usize(v, "service.max_pending")?;
        }
        if let Some(v) = root.get_path("service.max_batch") {
            cfg.max_batch = pos_usize(v, "service.max_batch")?;
        }
        if let Some(v) = root.get_path("service.shed_policy") {
            let raw = req_str(v, "service.shed_policy")?;
            cfg.shed = ShedPolicy::parse(raw).ok_or_else(|| {
                field_err(
                    "service.shed_policy",
                    format!(
                        "unknown shed policy '{raw}' (valid: reject-newest, reject-lowest-queue)"
                    ),
                )
            })?;
        }
        if let Some(v) = root.get_path("service.shards") {
            cfg.shards = pos_usize(v, "service.shards")?;
        }
        Ok(cfg)
    }
}

fn req_str<'a>(v: &'a Value, field: &str) -> Result<&'a str, ConfigError> {
    v.as_str().ok_or_else(|| field_err(field, "expected string"))
}
fn req_int(v: &Value, field: &str) -> Result<i64, ConfigError> {
    v.as_int().ok_or_else(|| field_err(field, "expected integer"))
}
fn pos_usize(v: &Value, field: &str) -> Result<usize, ConfigError> {
    let i = req_int(v, field)?;
    if i <= 0 {
        return Err(field_err(field, "must be positive"));
    }
    Ok(i as usize)
}
fn pos_f64(v: &Value, field: &str) -> Result<f64, ConfigError> {
    let f = v.as_f64().ok_or_else(|| field_err(field, "expected number"))?;
    if f <= 0.0 {
        return Err(field_err(field, "must be positive"));
    }
    Ok(f)
}
fn nonneg_f64(v: &Value, field: &str) -> Result<f64, ConfigError> {
    let f = v.as_f64().ok_or_else(|| field_err(field, "expected number"))?;
    if f < 0.0 {
        return Err(field_err(field, "must be non-negative"));
    }
    Ok(f)
}
fn unit_f64(v: &Value, field: &str) -> Result<f64, ConfigError> {
    let f = v.as_f64().ok_or_else(|| field_err(field, "expected number"))?;
    if !(0.0..=1.0).contains(&f) {
        return Err(field_err(field, "must be in [0, 1]"));
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[experiment]
name = "fig6-cpu"
seed = 7
horizon_hours = 168
history_hours = 336

[cluster]
capacity = 150
hardware = "cpu"
region = "south-australia"

[workload]
trace = "azure"
elasticity = "mix"
target_utilization = 0.5

[[queue]]
name = "short"
max_len_hours = 2.0
delay_hours = 6.0

[[queue]]
name = "medium"
max_len_hours = 12.0
delay_hours = 24.0

[[queue]]
name = "long"
delay_hours = 48.0
"#;

    #[test]
    fn parses_sample() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.name, "fig6-cpu");
        assert_eq!(cfg.capacity, 150);
        assert_eq!(cfg.hardware, Hardware::Cpu);
        assert_eq!(cfg.queues.len(), 3);
        assert!(cfg.queues[2].max_len_hours.is_infinite());
    }

    #[test]
    fn defaults_apply() {
        let cfg = ExperimentConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.capacity, 150);
        assert_eq!(cfg.knn_k, 5);
        assert_eq!(cfg.queues.len(), 3);
    }

    #[test]
    fn queue_routing() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.queue_for_length(1.0), 0);
        assert_eq!(cfg.queue_for_length(5.0), 1);
        assert_eq!(cfg.queue_for_length(100.0), 2);
        assert_eq!(cfg.slack_for_length(1.0), 6.0);
        assert_eq!(cfg.slack_for_length(100.0), 48.0);
    }

    #[test]
    fn uniform_delay_override() {
        let mut cfg = ExperimentConfig::default();
        cfg.uniform_delay_hours = Some(12.0);
        assert_eq!(cfg.slack_for_length(0.5), 12.0);
        assert_eq!(cfg.slack_for_length(99.0), 12.0);
    }

    #[test]
    fn dag_shape_parses_and_defaults() {
        let cfg = ExperimentConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.dag_shape, DagShape::None);
        let cfg =
            ExperimentConfig::from_toml_str("[workload]\ndag_shape = \"mapreduce\"\n").unwrap();
        assert_eq!(cfg.dag_shape, DagShape::MapReduce);
        // Round-trip: every shape parses from its own as_str (plus aliases).
        for s in [
            DagShape::None,
            DagShape::Chains,
            DagShape::Fanout,
            DagShape::MapReduce,
            DagShape::Random,
        ] {
            assert_eq!(DagShape::parse(s.as_str()).unwrap(), s);
        }
        assert_eq!(DagShape::parse("map-reduce").unwrap(), DagShape::MapReduce);
        assert_eq!(DagShape::parse("fan-out").unwrap(), DagShape::Fanout);
        assert!(DagShape::parse("lattice").is_err());
        assert!(
            ExperimentConfig::from_toml_str("[workload]\ndag_shape = \"lattice\"\n").is_err()
        );
        // The history config is always flat — DAG cells measure transfer of
        // flat-learned decisions, and replay learning never sees edges.
        let mut shaped = ExperimentConfig::default();
        shaped.dag_shape = DagShape::Chains;
        assert_eq!(shaped.unshifted_history().dag_shape, DagShape::None);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_toml_str("[cluster]\ncapacity = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[cluster]\nhardware = \"tpu\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str(
            "[workload]\ntarget_utilization = 1.5\n"
        )
        .is_err());
        // horizon > history
        assert!(ExperimentConfig::from_toml_str(
            "[experiment]\nhorizon_hours = 500\nhistory_hours = 100\n"
        )
        .is_err());
    }

    #[test]
    fn service_table_parses_and_coexists() {
        let src = r#"
[cluster]
capacity = 24

[service]
max_pending = 512
max_batch = 64
shed_policy = "reject-lowest-queue"
shards = 2
"#;
        // The experiment parser ignores [service]; the service parser reads it.
        let cfg = ExperimentConfig::from_toml_str(src).unwrap();
        assert_eq!(cfg.capacity, 24);
        let svc = ServiceConfig::from_toml_str(src).unwrap();
        assert_eq!(svc.max_pending, 512);
        assert_eq!(svc.max_batch, 64);
        assert_eq!(svc.shed, ShedPolicy::RejectLowestQueue);
        assert_eq!(svc.shards, 2);
        // Defaults apply when the table is absent; bad values are errors.
        assert_eq!(ServiceConfig::from_toml_str("").unwrap(), ServiceConfig::default());
        assert!(ServiceConfig::from_toml_str("[service]\nmax_pending = 0\n").is_err());
        assert!(ServiceConfig::from_toml_str("[service]\nshed_policy = \"coin-flip\"\n").is_err());
        for p in ShedPolicy::ALL {
            assert_eq!(ShedPolicy::parse(p.as_str()), Some(p));
        }
    }

    #[test]
    fn rejects_unordered_queues() {
        let bad = r#"
[[queue]]
name = "a"
max_len_hours = 12.0
delay_hours = 6.0
[[queue]]
name = "b"
max_len_hours = 2.0
delay_hours = 24.0
"#;
        assert!(ExperimentConfig::from_toml_str(bad).is_err());
    }
}
